//! Artifact manifest: the JSON file `python/compile/aot.py` writes next
//! to the HLO-text artifacts, describing each variant's geometry and
//! argument order.

use std::path::Path;

use crate::error::{Error, Result};
use crate::json;

/// One argument of a variant.
#[derive(Debug, Clone, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT-compiled graph variant.
#[derive(Debug, Clone)]
pub struct Variant {
    pub name: String,
    pub file: String,
    /// "gather" | "scatter" | "gather_checksum" | "scatter_checksum".
    pub kernel: String,
    /// "pallas" (through the L1 kernel) or "ref" (jnp oracle).
    pub family: String,
    /// Index-buffer length.
    pub v: usize,
    /// Gathers/scatters per execution.
    pub count: usize,
    /// Source/destination array length.
    pub n: usize,
    pub args: Vec<ArgSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub variants: Vec<Variant>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read manifest {} ({e}) — run `make artifacts`",
                path.display()
            ))
        })?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = json::parse(text)?;
        let fmt = root.get("format")?.as_str()?;
        if fmt != "hlo-text" {
            return Err(Error::Runtime(format!(
                "unsupported artifact format '{fmt}'"
            )));
        }
        let mut variants = Vec::new();
        for v in root.get("variants")?.as_array()? {
            let args = v
                .get("args")?
                .as_array()?
                .iter()
                .map(|a| {
                    Ok(ArgSpec {
                        name: a.get("name")?.as_str()?.to_string(),
                        shape: a
                            .get("shape")?
                            .as_array()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<Vec<_>>>()?,
                        dtype: a.get("dtype")?.as_str()?.to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            variants.push(Variant {
                name: v.get("name")?.as_str()?.to_string(),
                file: v.get("file")?.as_str()?.to_string(),
                kernel: v.get("kernel")?.as_str()?.to_string(),
                family: v.get("family")?.as_str()?.to_string(),
                v: v.get("v")?.as_usize()?,
                count: v.get("count")?.as_usize()?,
                n: v.get("n")?.as_usize()?,
                args,
            });
        }
        Ok(Manifest { variants })
    }

    pub fn by_name(&self, name: &str) -> Option<&Variant> {
        self.variants.iter().find(|v| v.name == name)
    }

    /// Find a variant by kernel/family/index-length, optionally pinning
    /// the per-execution count.
    pub fn find(
        &self,
        kernel: &str,
        family: &str,
        v: usize,
        count: Option<usize>,
    ) -> Option<&Variant> {
        self.variants.iter().find(|x| {
            x.kernel == kernel
                && x.family == family
                && x.v == v
                && count.map_or(true, |c| x.count == c)
        })
    }

    /// The largest-count variant matching kernel/family/v (preferred
    /// for throughput timing); ties prefer the smallest source array
    /// (§Perf: smaller buffers mean smaller per-execution copies).
    pub fn find_largest(
        &self,
        kernel: &str,
        family: &str,
        v: usize,
    ) -> Option<&Variant> {
        self.variants
            .iter()
            .filter(|x| x.kernel == kernel && x.family == family && x.v == v)
            .max_by_key(|x| (x.count, std::cmp::Reverse(x.n)))
    }

    /// Index-buffer lengths available for a kernel/family.
    pub fn available_v(&self, kernel: &str, family: &str) -> Vec<usize> {
        let mut vs: Vec<usize> = self
            .variants
            .iter()
            .filter(|x| x.kernel == kernel && x.family == family)
            .map(|x| x.v)
            .collect();
        vs.sort_unstable();
        vs.dedup();
        vs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "variants": [
        {"name": "gather_ref_v8_c64_n4096", "file": "gather_ref_v8_c64_n4096.hlo.txt",
         "kernel": "gather", "family": "ref", "v": 8, "count": 64, "n": 4096,
         "dtype": "f64",
         "args": [
           {"name": "src", "shape": [4096], "dtype": "f64"},
           {"name": "idx", "shape": [8], "dtype": "s32"},
           {"name": "delta", "shape": [1], "dtype": "s32"}],
         "out": {"shape": [64, 8], "dtype": "f64"}},
        {"name": "gather_ref_v8_c4096_n64", "file": "g2.hlo.txt",
         "kernel": "gather", "family": "ref", "v": 8, "count": 4096, "n": 64,
         "dtype": "f64", "args": [], "out": {"shape": [4096, 8], "dtype": "f64"}},
        {"name": "scatter_pallas_v16_c64_n4096", "file": "s.hlo.txt",
         "kernel": "scatter", "family": "pallas", "v": 16, "count": 64, "n": 4096,
         "dtype": "f64", "args": [], "out": {"shape": [4096], "dtype": "f64"}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.variants.len(), 3);
        let g = m.by_name("gather_ref_v8_c64_n4096").unwrap();
        assert_eq!(g.v, 8);
        assert_eq!(g.count, 64);
        assert_eq!(g.args.len(), 3);
        assert_eq!(g.args[1].name, "idx");
        assert_eq!(g.args[1].shape, vec![8]);
    }

    #[test]
    fn find_variants() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.find("gather", "ref", 8, Some(64)).is_some());
        assert!(m.find("gather", "ref", 8, Some(65)).is_none());
        assert!(m.find("gather", "pallas", 8, None).is_none());
        assert_eq!(m.find_largest("gather", "ref", 8).unwrap().count, 4096);
        assert_eq!(m.available_v("gather", "ref"), vec![8]);
        assert_eq!(m.available_v("scatter", "pallas"), vec![16]);
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse(r#"{"format": "proto", "variants": []}"#).is_err());
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("[]").is_err());
    }
}
