//! PJRT runtime: load AOT HLO-text artifacts and execute them from the
//! Rust hot path. Python never runs here — `make artifacts` produced
//! `artifacts/*.hlo.txt` + `manifest.json` at build time.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! protos with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example).
//!
//! The `xla` crate comes from the offline vendor set, which not every
//! build environment carries. The real runtime is therefore gated
//! behind the `xla` cargo feature; the default build compiles a stub
//! whose constructors return a clear "built without PJRT support"
//! error. The stub's value types are uninhabited, so all downstream
//! code (the pjrt backend, the e2e tests) typechecks unchanged and the
//! unreachable paths cost nothing.

mod manifest;

pub use manifest::{ArgSpec, Manifest, Variant};

use std::path::PathBuf;

/// Locate the artifacts directory: `$SPATTER_ARTIFACTS`, else
/// `./artifacts`, else `../artifacts` (for tests run from rust/).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("SPATTER_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    for cand in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(cand);
        if p.join("manifest.json").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

#[cfg(feature = "xla")]
mod backend_impl {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use super::Manifest;
    use crate::error::{Error, Result};

    pub use xla::{Literal, PjRtBuffer};

    /// Map an `xla` crate error into ours.
    fn xe(e: xla::Error) -> Error {
        Error::Xla(e.to_string())
    }

    /// The runtime: a PJRT CPU client plus a compile cache of loaded
    /// executables, one per artifact variant.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        manifest: Manifest,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Runtime {
        /// Open the runtime over an artifact directory.
        pub fn open(dir: &Path) -> Result<Runtime> {
            let manifest = Manifest::load(&dir.join("manifest.json"))?;
            let client = xla::PjRtClient::cpu().map_err(xe)?;
            Ok(Runtime {
                client,
                dir: dir.to_path_buf(),
                manifest,
                cache: HashMap::new(),
            })
        }

        /// Open using the default artifact location.
        pub fn open_default() -> Result<Runtime> {
            Runtime::open(&super::default_artifact_dir())
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform_name(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch from cache) an executable for a variant.
        pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.cache.contains_key(name) {
                let variant = self
                    .manifest
                    .by_name(name)
                    .ok_or_else(|| Error::Runtime(format!("no variant '{name}'")))?;
                let path = self.dir.join(&variant.file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str()
                        .ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
                )
                .map_err(xe)?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self.client.compile(&comp).map_err(xe)?;
                self.cache.insert(name.to_string(), exe);
            }
            Ok(&self.cache[name])
        }

        /// Stage a f64 host array on the device.
        pub fn stage_f64(&self, data: &[f64]) -> Result<PjRtBuffer> {
            self.client
                .buffer_from_host_buffer(data, &[data.len()], None)
                .map_err(xe)
        }

        /// Stage a 2-D f64 host array on the device.
        pub fn stage_f64_2d(
            &self,
            data: &[f64],
            rows: usize,
            cols: usize,
        ) -> Result<PjRtBuffer> {
            self.client
                .buffer_from_host_buffer(data, &[rows, cols], None)
                .map_err(xe)
        }

        /// Stage an i32 host array on the device.
        pub fn stage_i32(&self, data: &[i32]) -> Result<PjRtBuffer> {
            self.client
                .buffer_from_host_buffer(data, &[data.len()], None)
                .map_err(xe)
        }

        /// Execute a loaded variant over staged buffers; returns the result
        /// tuple's first element as a Literal (synchronized).
        pub fn execute(
            &mut self,
            name: &str,
            args: &[&PjRtBuffer],
        ) -> Result<Literal> {
            self.load(name)?;
            let exe = &self.cache[name];
            let outs = exe.execute_b(args).map_err(xe)?;
            let lit = outs[0][0].to_literal_sync().map_err(xe)?;
            lit.to_tuple1().map_err(xe)
        }

        /// Execute and return the scalar f64 result (checksum variants).
        pub fn execute_scalar(
            &mut self,
            name: &str,
            args: &[&PjRtBuffer],
        ) -> Result<f64> {
            let lit = self.execute(name, args)?;
            lit.get_first_element::<f64>().map_err(xe)
        }
    }
}

#[cfg(not(feature = "xla"))]
mod backend_impl {
    //! Stub runtime for builds without the vendored `xla` crate.
    //!
    //! `Runtime`, `PjRtBuffer`, and `Literal` are uninhabited: the only
    //! way to obtain one is through `open`/`open_default`, which always
    //! fail with a descriptive error, so every downstream method body
    //! is statically unreachable (`match` on the uninhabited field).

    use std::convert::Infallible;
    use std::path::Path;

    use super::Manifest;
    use crate::error::{Error, Result};

    const NO_XLA: &str = "spatter was built without the `xla` feature; \
                          the PJRT real-execution backend is unavailable \
                          (rebuild with `--features xla` and the vendored \
                          xla crate)";

    /// Uninhabited stand-in for `xla::PjRtBuffer`.
    pub enum PjRtBuffer {}

    /// Uninhabited stand-in for `xla::Literal`.
    pub struct Literal {
        never: Infallible,
    }

    impl Literal {
        pub fn to_vec<T>(&self) -> Result<Vec<T>> {
            match self.never {}
        }

        pub fn get_first_element<T>(&self) -> Result<T> {
            match self.never {}
        }
    }

    /// Stub runtime: constructors fail, everything else is unreachable.
    pub struct Runtime {
        never: Infallible,
    }

    impl Runtime {
        pub fn open(_dir: &Path) -> Result<Runtime> {
            Err(Error::Runtime(NO_XLA.to_string()))
        }

        pub fn open_default() -> Result<Runtime> {
            Runtime::open(&super::default_artifact_dir())
        }

        pub fn manifest(&self) -> &Manifest {
            match self.never {}
        }

        pub fn platform_name(&self) -> String {
            match self.never {}
        }

        pub fn stage_f64(&self, _data: &[f64]) -> Result<PjRtBuffer> {
            match self.never {}
        }

        pub fn stage_f64_2d(
            &self,
            _data: &[f64],
            _rows: usize,
            _cols: usize,
        ) -> Result<PjRtBuffer> {
            match self.never {}
        }

        pub fn stage_i32(&self, _data: &[i32]) -> Result<PjRtBuffer> {
            match self.never {}
        }

        pub fn execute(
            &mut self,
            _name: &str,
            _args: &[&PjRtBuffer],
        ) -> Result<Literal> {
            match self.never {}
        }

        pub fn execute_scalar(
            &mut self,
            _name: &str,
            _args: &[&PjRtBuffer],
        ) -> Result<f64> {
            match self.never {}
        }
    }
}

pub use backend_impl::{Literal, PjRtBuffer, Runtime};

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        default_artifact_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_discovery() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let rt = match Runtime::open_default() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: {e}");
                return;
            }
        };
        assert!(rt.manifest().variants.len() >= 10);
    }

    #[test]
    fn stub_or_real_open_reports_clearly() {
        // Without artifacts (or without the xla feature) opening must
        // fail with a descriptive error, never panic.
        if have_artifacts() && cfg!(feature = "xla") {
            return; // covered by the e2e tests
        }
        let err = Runtime::open_default().unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("artifacts") || msg.contains("xla"),
            "unhelpful error: {msg}"
        );
    }

    #[test]
    fn smoke_gather_executes_correctly() {
        if !have_artifacts() || !cfg!(feature = "xla") {
            eprintln!("skipping: no artifacts or no xla feature");
            return;
        }
        let mut rt = Runtime::open_default().unwrap();
        // Smoke geometry: gather_ref_v8_c64_n4096.
        let v = rt
            .manifest()
            .find("gather", "ref", 8, Some(64))
            .expect("smoke gather variant")
            .clone();
        let src: Vec<f64> = (0..v.n).map(|i| i as f64).collect();
        let idx: Vec<i32> = (0..8).map(|j| (j * 2) as i32).collect();
        let delta = vec![8i32];
        let sb = rt.stage_f64(&src).unwrap();
        let ib = rt.stage_i32(&idx).unwrap();
        let db = rt.stage_i32(&delta).unwrap();
        let out = rt.execute(&v.name, &[&sb, &ib, &db]).unwrap();
        let vals = out.to_vec::<f64>().unwrap();
        assert_eq!(vals.len(), 64 * 8);
        // out[i,j] = src[8*i + 2*j] = 8i + 2j
        for i in 0..64 {
            for j in 0..8 {
                assert_eq!(vals[i * 8 + j], (8 * i + 2 * j) as f64, "({i},{j})");
            }
        }
    }

    #[test]
    fn checksum_matches_host_computation() {
        if !have_artifacts() || !cfg!(feature = "xla") {
            eprintln!("skipping: no artifacts or no xla feature");
            return;
        }
        let mut rt = Runtime::open_default().unwrap();
        let v = rt
            .manifest()
            .find("gather_checksum", "ref", 8, Some(64))
            .expect("smoke checksum variant")
            .clone();
        let src: Vec<f64> = (0..v.n).map(|i| (i % 97) as f64 * 0.5).collect();
        let idx: Vec<i32> = vec![0, 3, 9, 1, 7, 7, 2, 5];
        let delta = vec![4i32];
        let expected: f64 = (0..64)
            .flat_map(|i| idx.iter().map(move |&ix| (4 * i + ix) as usize))
            .map(|a| src[a])
            .sum();
        let sb = rt.stage_f64(&src).unwrap();
        let ib = rt.stage_i32(&idx).unwrap();
        let db = rt.stage_i32(&delta).unwrap();
        let got = rt.execute_scalar(&v.name, &[&sb, &ib, &db]).unwrap();
        assert!((got - expected).abs() < 1e-9, "{got} vs {expected}");
    }

    #[test]
    fn pallas_and_ref_variants_agree() {
        if !have_artifacts() || !cfg!(feature = "xla") {
            eprintln!("skipping: no artifacts or no xla feature");
            return;
        }
        let mut rt = Runtime::open_default().unwrap();
        let vp = rt.manifest().find("gather", "pallas", 8, Some(64)).cloned();
        let vr = rt.manifest().find("gather", "ref", 8, Some(64)).cloned();
        let (vp, vr) = match (vp, vr) {
            (Some(a), Some(b)) => (a, b),
            _ => return,
        };
        let src: Vec<f64> = (0..vr.n).map(|i| ((i * 37) % 1009) as f64).collect();
        let idx: Vec<i32> = vec![5, 0, 2, 63, 11, 8, 1, 30];
        let delta = vec![7i32];
        let sb = rt.stage_f64(&src).unwrap();
        let ib = rt.stage_i32(&idx).unwrap();
        let db = rt.stage_i32(&delta).unwrap();
        let a = rt
            .execute(&vp.name, &[&sb, &ib, &db])
            .unwrap()
            .to_vec::<f64>()
            .unwrap();
        let b = rt
            .execute(&vr.name, &[&sb, &ib, &db])
            .unwrap()
            .to_vec::<f64>()
            .unwrap();
        assert_eq!(a, b, "L1 Pallas kernel must match the jnp oracle in HLO");
    }
}
