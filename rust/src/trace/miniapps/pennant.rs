//! PENNANT 0.9 — `Hydro::doCycle`, `Mesh::calcSurfVecs`,
//! `QCS::setForce`, `QCS::setQCnForce` (Table 2: sedovflat,
//! `meshparams 1920 2160`, cstop 5).
//!
//! PENNANT is a staggered-grid Lagrangian hydro code over an
//! unstructured quad mesh; the sedovflat mesh is logically rectangular
//! with ~480 sides per row in each rank-local chunk, which is where the
//! 480/482/484 constants in Table 5's edge buffers come from:
//!
//! * side loops gather the two endpoints of each edge plus the
//!   wrap-around pair of the neighbouring row — the
//!   `[2,484,482,0, 4,486,484,2, ...]` buffers (G0/G1) marching with
//!   delta 2, and the same buffers at row-pitch deltas 480/482 (G6/G7).
//! * zone-to-corner broadcasts: each zone value feeds its 4 corners —
//!   `[0,0,0,0,1,1,1,1,2,2,2,2,3,3,3,3]` (G4) with delta 4 in the
//!   side-major phase and at chunk-pitch deltas in the later passes
//!   (G9–G11, G15).
//! * corner-major quad gathers `[4,8,12,0, 20,24,28,16, ...]` (G3/G5)
//!   and `[6,0,2,4, 14,8,10,12, ...]` (G12–G14) — rotated corner
//!   numbering, at small and chunk-pitch deltas.
//! * `[2,0,0,0,...]` (G8): the first-point-of-zone load with three
//!   masked-off lanes repeating per chunk.

use crate::trace::KernelTrace;

/// Sides per mesh row in a rank-local chunk (the 480/482/484 family).
pub const ROW: i64 = 480;
/// Rank-local chunk pitches observed between kernel passes (element
/// units). These reproduce Table 5's large deltas exactly:
/// 129608 ≈ one side-chunk, 388848/388852 ≈ one zone-array pass,
/// 518408 ≈ one corner-array pass, 1036816 = two corner passes,
/// 1882384 ≈ the full-mesh point array.
pub const CHUNK_SIDES: i64 = 129_608;
pub const CHUNK_ZONES: i64 = 388_848;
pub const CHUNK_CORNERS: i64 = 518_408;
pub const CHUNK_POINTS: i64 = 1_882_384;

/// Rows emulated per kernel pass (scaled from the real mesh).
const ROWS: i64 = 64;

/// The edge-pair buffer of G0: lane groups (p2, p2+row+2, p2+row,
/// p1) per side.
fn edge_buf_g0() -> Vec<i64> {
    let mut v = Vec::with_capacity(16);
    for s in 0..4i64 {
        let p = 2 * s;
        v.extend_from_slice(&[p + 2, p + ROW + 4, p + ROW + 2, p]);
    }
    v
}

/// The edge-pair buffer of G1: rotated lane order (p1, p2, ...).
fn edge_buf_g1() -> Vec<i64> {
    let mut v = Vec::with_capacity(16);
    for s in 0..4i64 {
        let p = 2 * s;
        v.extend_from_slice(&[p, p + 2, p + ROW + 4, p + ROW + 2]);
    }
    v
}

fn broadcast_buf() -> Vec<i64> {
    (0..16).map(|j| j / 4).collect()
}

fn quad_buf() -> Vec<i64> {
    // [4,8,12,0, 20,24,28,16, ...] — rotated corner numbering.
    (0..16)
        .map(|j| {
            let group = j / 4;
            let lane = j % 4;
            group * 16 + ((lane + 1) % 4) * 4
        })
        .collect()
}

fn quad2_buf() -> Vec<i64> {
    // [6,0,2,4, 14,8,10,12, ...]
    (0..16)
        .map(|j| {
            let group = j / 4;
            let lane = j % 4;
            group * 8 + ((lane + 3) % 4) * 2
        })
        .collect()
}

fn first_point_buf() -> Vec<i64> {
    // [2,0,0,0, 2,0,0,0, ...] — first-point loads with masked lanes.
    (0..16).map(|j| if j % 4 == 0 { 2 } else { 0 }).collect()
}

/// `Hydro::doCycle` — the main cycle: point gathers along side rows
/// (G0/G1 at delta 2), corner quads (G3 at delta 2), and zone
/// broadcasts (G4 at delta 4).
pub fn hydro_do_cycle(scale: usize) -> KernelTrace {
    let mut t = KernelTrace::new("PENNANT", "Hydro::doCycle");
    let g0 = edge_buf_g0();
    let g1 = edge_buf_g1();
    let g3 = quad_buf();
    let g4 = broadcast_buf();
    for _ in 0..scale {
        // Side-major point gathers, marching two points per vector.
        for s in 0..ROWS * 8 {
            t.gather(2 * s, &g0);
        }
        for s in 0..ROWS * 8 {
            t.gather(2 * s, &g1);
        }
        // Corner-major quads.
        for s in 0..ROWS * 4 {
            t.gather(2 * s, &g3);
        }
        // Zone-to-corner broadcast.
        for z in 0..ROWS * 4 {
            t.gather(4 * z, &g4);
        }
        // Side/zone state loads, EOS math, accumulator stores —
        // calibrated to Table 1's 13.9% G/S share for doCycle.
        t.scalar_loads += (ROWS * 2000) as u64;
        t.scalar_stores += (ROWS * 380) as u64;
    }
    t
}

/// `Mesh::calcSurfVecs` — surface vectors per side: stride-4 component
/// gathers (G2, delta 2) and the side scatter (S0, delta 1).
pub fn calc_surf_vecs(scale: usize) -> KernelTrace {
    let mut t = KernelTrace::new("PENNANT", "Mesh::calcSurfVecs");
    let s4: Vec<i64> = (0..16).map(|i| i * 4).collect();
    for _ in 0..scale {
        for s in 0..ROWS * 4 {
            t.gather(2 * s, &s4);
        }
        for s in 0..ROWS * 4 {
            t.scatter(s, &s4);
        }
        // Table 1: 39.5% G/S share for calcSurfVecs.
        t.scalar_loads += (ROWS * 150) as u64;
        t.scalar_stores += (ROWS * 45) as u64;
    }
    t
}

/// `QCS::setForce` — edge gathers at row pitch (G6/G7) and the
/// rotated quad at delta 4 (G5).
pub fn set_force(scale: usize) -> KernelTrace {
    let mut t = KernelTrace::new("PENNANT", "QCS::setForce");
    let edge0 = {
        // G6/G7 buffer: [482,0,2,484, 484,2,4,486, ...]
        let mut v = Vec::with_capacity(16);
        for s in 0..4i64 {
            let p = 2 * s;
            v.extend_from_slice(&[p + ROW + 2, p, p + 2, p + ROW + 4]);
        }
        v
    };
    let g5 = quad_buf();
    for _ in 0..scale {
        // Row-major pass: pitch ROW (G6).
        for r in 0..ROWS {
            t.gather(r * ROW, &edge0);
        }
        // Diagonal pass: pitch ROW + 2 (G7).
        for r in 0..ROWS {
            t.gather(r * (ROW + 2), &edge0);
        }
        for s in 0..ROWS * 2 {
            t.gather(4 * s, &g5);
        }
        // Table 1: 45.5% G/S share for setForce.
        t.scalar_loads += (ROWS * 70) as u64;
        t.scalar_stores += (ROWS * 7) as u64;
    }
    t
}

/// `QCS::setQCnForce` — the chunk-strided passes: broadcasts and quads
/// at the large Table 5 deltas (G8–G15), plus a scatter phase.
pub fn set_qcn_force(scale: usize) -> KernelTrace {
    let mut t = KernelTrace::new("PENNANT", "QCS::setQCnForce");
    let bcast = broadcast_buf();
    let q2 = quad2_buf();
    let fp = first_point_buf();
    let s4: Vec<i64> = (0..16).map(|i| i * 4).collect();
    let chunks = 8i64;
    for _ in 0..scale {
        // G8: first-point loads, one per side-chunk.
        for c in 0..chunks {
            t.gather(c * CHUNK_SIDES, &fp);
        }
        // G9/G10/G11: zone broadcasts at zone-pass pitch (the paper
        // lists the buffer three times: three consecutive QCS passes).
        for pass in 0..3 {
            for c in 0..chunks {
                t.gather(pass * 4 + c * (CHUNK_ZONES + if pass == 0 { 4 } else { 0 }), &bcast);
            }
        }
        // G12/G13: corner quads at corner-pass pitch; G14 at double.
        for c in 0..chunks {
            t.gather(c * CHUNK_CORNERS, &q2);
        }
        for c in 0..chunks {
            t.gather(c * CHUNK_CORNERS, &q2);
        }
        for c in 0..chunks {
            t.gather(c * 2 * CHUNK_CORNERS, &q2);
        }
        // G15: point-array broadcast at full-mesh pitch.
        for c in 0..chunks {
            t.gather(c * CHUNK_POINTS, &bcast);
        }
        // The scatter phase (Table 1: ~324k scatters in setQCnForce).
        for s in 0..chunks * 8 {
            t.scatter(s, &s4);
        }
        // Table 1: 64.5% G/S share for setQCnForce.
        t.scalar_loads += (chunks * 130) as u64;
        t.scalar_stores += (chunks * 10) as u64;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::{table5, Kernel, PatternClass};
    use crate::trace::extract::extract_from_trace;

    #[test]
    fn buffers_match_table5_exactly() {
        assert_eq!(edge_buf_g0(), table5::by_name("PENNANT-G0").unwrap().indices);
        assert_eq!(edge_buf_g1(), table5::by_name("PENNANT-G1").unwrap().indices);
        assert_eq!(broadcast_buf(), table5::by_name("PENNANT-G4").unwrap().indices);
        assert_eq!(quad_buf(), table5::by_name("PENNANT-G3").unwrap().indices);
        assert_eq!(quad2_buf(), table5::by_name("PENNANT-G12").unwrap().indices);
        assert_eq!(first_point_buf(), table5::by_name("PENNANT-G8").unwrap().indices);
    }

    #[test]
    fn do_cycle_recovers_edge_and_broadcast() {
        let pats = extract_from_trace(&hydro_do_cycle(1), 0);
        let g0 = table5::by_name("PENNANT-G0").unwrap();
        let e = pats
            .iter()
            .find(|p| p.indices == g0.indices)
            .expect("G0 cluster");
        assert_eq!(e.delta, 2);
        let g4 = table5::by_name("PENNANT-G4").unwrap();
        let b = pats
            .iter()
            .find(|p| p.indices == g4.indices)
            .expect("G4 cluster");
        assert_eq!(b.delta, 4);
        assert_eq!(b.class, PatternClass::Broadcast);
    }

    #[test]
    fn set_force_recovers_row_pitch_deltas() {
        let pats = extract_from_trace(&set_force(1), 0);
        let g6 = table5::by_name("PENNANT-G6").unwrap();
        let e = pats
            .iter()
            .find(|p| p.indices == g6.indices)
            .expect("edge cluster");
        // Two interleaved pitches (480 and 482); modal is one of them.
        assert!([480, 482].contains(&e.delta), "delta {}", e.delta);
    }

    #[test]
    fn qcn_force_recovers_large_deltas() {
        let pats = extract_from_trace(&set_qcn_force(1), 0);
        let g9 = table5::by_name("PENNANT-G9").unwrap();
        let bcasts: Vec<&_> = pats
            .iter()
            .filter(|p| p.kernel == Kernel::Gather && p.indices == g9.indices)
            .collect();
        assert!(!bcasts.is_empty());
        assert!(
            bcasts.iter().any(|p| p.delta >= 388_848),
            "deltas {:?}",
            bcasts.iter().map(|p| p.delta).collect::<Vec<_>>()
        );
        let g12 = table5::by_name("PENNANT-G12").unwrap();
        let quads = pats
            .iter()
            .find(|p| p.indices == g12.indices)
            .expect("quad2 cluster");
        assert_eq!(quads.delta, 518_408);
        let g8 = table5::by_name("PENNANT-G8").unwrap();
        let fp = pats
            .iter()
            .find(|p| p.indices == g8.indices)
            .expect("first-point cluster");
        assert_eq!(fp.delta, 129_608);
    }

    #[test]
    fn calc_surf_vecs_has_gathers_and_scatters() {
        // Table 1 lists calcSurfVecs gathers; PENNANT-S0 is the
        // stride-4 scatter with delta 1.
        let pats = extract_from_trace(&calc_surf_vecs(1), 0);
        let s0 = table5::by_name("PENNANT-S0").unwrap();
        let sc = pats
            .iter()
            .find(|p| p.kernel == Kernel::Scatter && p.indices == s0.indices)
            .expect("S0 cluster");
        assert_eq!(sc.delta, 1);
        let g2 = table5::by_name("PENNANT-G2").unwrap();
        let ga = pats
            .iter()
            .find(|p| p.kernel == Kernel::Gather && p.indices == g2.indices)
            .expect("G2 cluster");
        assert_eq!(ga.delta, 2);
    }
}
