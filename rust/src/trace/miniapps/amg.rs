//! AMG (LLNL algebraic multigrid benchmark) — the
//! `hypre_CSRMatrixMatvecOutOfPlace` hot kernel (Table 2:
//! `-problem 1 -n 36 36 36 -P 4 4 4`).
//!
//! Problem 1 assembles a 27-point Laplacian on a 36³ grid; hypre's CSR
//! stores the diagonal entry *first*, then the off-diagonals in column
//! order. The vectorized SpMV gathers `x[colidx[k .. k+16]]` — for an
//! interior row the first 16 columns are
//! `[diag, all 26 neighbours in ascending order][..16]`, which after
//! zero-normalization is exactly the paper's AMG-G0 buffer
//! `[1333, 0, 1, 36, 37, 72, 73, 1296, 1297, 1332, 1368, 1369, 2592,
//!   2593, 2628, 2629]` with delta 1 (consecutive rows).

use crate::trace::{KernelTrace, SVE_LANES};

/// Grid edge (paper: -n 36 36 36).
pub const N: i64 = 36;

/// 27-point stencil column offsets for a point of an N³ grid in hypre
/// layout: diagonal first, then off-diagonals ascending. `clip_xmax`
/// prunes the dx=+1 neighbours (a row on the local x-max boundary) and
/// `clip_xmin` the dx=-1 ones.
fn stencil_columns(clip_xmin: bool, clip_xmax: bool) -> Vec<i64> {
    let mut offs = Vec::with_capacity(27);
    for dz in -1i64..=1 {
        for dy in -1i64..=1 {
            for dx in -1i64..=1 {
                if (dx, dy, dz) == (0, 0, 0)
                    || (clip_xmax && dx == 1)
                    || (clip_xmin && dx == -1)
                {
                    continue;
                }
                offs.push(dz * N * N + dy * N + dx);
            }
        }
    }
    offs.sort_unstable();
    let mut cols = vec![0i64]; // diagonal first
    cols.extend(offs);
    cols
}

/// Emulate the SpMV over `scale` sweeps of the local 36³ block,
/// emitting one 16-lane gather per 16 columns of each row (a full
/// stencil has 27 columns: one full vector + a scalar tail).
///
/// Interior rows produce the paper's AMG-G1 buffer; x-boundary rows
/// (pruned stencil) produce exactly AMG-G0.
pub fn matvec_out_of_place(scale: usize) -> KernelTrace {
    let mut t = KernelTrace::new("AMG", "hypre_CSRMatrixMatvecOutOfPlace");
    let interior = stencil_columns(false, false);
    let xmax = stencil_columns(false, true);
    let xmin = stencil_columns(true, false);
    for _ in 0..scale {
        for z in 1..N - 1 {
            for y in 1..N - 1 {
                for x in 0..N {
                    let cols = if x == 0 {
                        &xmin
                    } else if x == N - 1 {
                        &xmax
                    } else {
                        &interior
                    };
                    let row = z * N * N + y * N + x;
                    // Vector body: first 16 columns.
                    let lanes: Vec<i64> =
                        cols[..SVE_LANES].iter().map(|c| row + c).collect();
                    let min = *lanes.iter().min().unwrap();
                    let offsets: Vec<i64> =
                        lanes.iter().map(|l| l - min).collect();
                    t.gather(min, &offsets);
                    // Scalar tail columns + result store + matrix value
                    // loads + colidx loads.
                    let ncols = cols.len() as u64;
                    t.scalar_loads += (ncols - SVE_LANES as u64) + 2 * ncols;
                    t.scalar_stores += 1;
                }
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::table5;
    use crate::trace::extract::extract_from_trace;

    #[test]
    fn stencil_has_27_points_diag_first() {
        let cols = stencil_columns(false, false);
        assert_eq!(cols.len(), 27);
        assert_eq!(cols[0], 0);
        assert!(cols[1..].windows(2).all(|w| w[0] < w[1]));
        assert_eq!(cols[1], -(N * N) - N - 1);
        assert_eq!(stencil_columns(false, true).len(), 18);
    }

    #[test]
    fn extraction_recovers_amg_g1_and_g0() {
        // Interior rows dominate -> top pattern is AMG-G1; the pruned
        // x-max boundary rows are AMG-G0 (both Table 5 rows).
        let trace = matvec_out_of_place(1);
        let pats = extract_from_trace(&trace, 3);
        let g1 = table5::by_name("AMG-G1").unwrap();
        assert_eq!(pats[0].indices, g1.indices, "top pattern must be AMG-G1");
        assert_eq!(pats[0].delta, g1.delta);
        let g0 = table5::by_name("AMG-G0").unwrap();
        let found = pats
            .iter()
            .find(|p| p.indices == g0.indices)
            .expect("AMG-G0 among top extracted patterns");
        // Boundary rows are N apart (one per grid line).
        assert_eq!(found.delta, N);
    }

    #[test]
    fn gathers_only_no_scatters() {
        // Table 1: AMG's matvec has 1.7M gathers, 0 scatters.
        let trace = matvec_out_of_place(1);
        assert!(trace.gather_count() > 0);
        assert_eq!(trace.scatter_count(), 0);
    }

    #[test]
    fn traffic_fraction_in_table1_ballpark() {
        // Table 1 reports 17.8% G/S traffic for this kernel.
        let f = matvec_out_of_place(1).gs_traffic_fraction();
        assert!((0.1..0.35).contains(&f), "fraction {f}");
    }
}
