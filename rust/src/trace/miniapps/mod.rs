//! Mini-app kernel emulators (the paper's Table 2 configurations,
//! scaled) — substitutes for the closed-source QEMU+SVE tracing rig.
//!
//! Each emulator executes the *loop-nest structure* of the named hot
//! kernel over a synthetic problem at the paper's geometry (grid 36³
//! for AMG, 40³ mesh for LULESH, 16³ spectral elements for Nekbone,
//! a 1920-zone-wide sedov mesh scaled down for PENNANT) and emits the
//! SVE-1024 G/S instruction records the vectorized kernel would issue,
//! plus scalar load/store counts for the Table 1 traffic column.
//!
//! The emulators are validated against the paper's own Table 5: the
//! extraction pipeline must recover those exact (index, delta) pairs.

pub mod amg;
pub mod lulesh;
pub mod nekbone;
pub mod pennant;

use super::KernelTrace;

/// All kernel traces of one application run.
#[derive(Debug, Clone)]
pub struct AppTraces {
    pub app: &'static str,
    pub kernels: Vec<KernelTrace>,
}

/// Run every emulator at a reduced iteration scale (iterations don't
/// change the patterns, only the record counts — paper §2: "multiple
/// kernel iterations will have many patterns in common").
pub fn run_all(scale: usize) -> Vec<AppTraces> {
    vec![
        AppTraces {
            app: "AMG",
            kernels: vec![amg::matvec_out_of_place(scale)],
        },
        AppTraces {
            app: "LULESH",
            kernels: vec![
                lulesh::integrate_stress_for_elems(scale),
                lulesh::init_stress_terms_for_elems(scale),
            ],
        },
        AppTraces {
            app: "Nekbone",
            kernels: vec![nekbone::ax_e(scale)],
        },
        AppTraces {
            app: "PENNANT",
            kernels: vec![
                pennant::hydro_do_cycle(scale),
                pennant::calc_surf_vecs(scale),
                pennant::set_force(scale),
                pennant::set_qcn_force(scale),
            ],
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_apps_produce_records() {
        for app in run_all(1) {
            for k in &app.kernels {
                assert!(
                    !k.records.is_empty(),
                    "{}::{} produced no records",
                    app.app,
                    k.kernel
                );
                // Table 1: G/S is a meaningful share of traffic
                assert!(
                    k.gs_traffic_fraction() > 0.05,
                    "{}::{} fraction {}",
                    app.app,
                    k.kernel,
                    k.gs_traffic_fraction()
                );
            }
        }
    }

    #[test]
    fn gathers_outnumber_scatters_overall() {
        // Table 1 observation: "gathers are more common than scatters".
        let (mut g, mut s) = (0u64, 0u64);
        for app in run_all(1) {
            for k in &app.kernels {
                g += k.gather_count();
                s += k.scatter_count();
            }
        }
        assert!(g > s, "gathers {g} vs scatters {s}");
    }

    #[test]
    fn scale_multiplies_record_counts() {
        let r1 = run_all(1);
        let r2 = run_all(2);
        let count = |apps: &[AppTraces]| -> usize {
            apps.iter()
                .flat_map(|a| a.kernels.iter())
                .map(|k| k.records.len())
                .sum()
        };
        let (c1, c2) = (count(&r1), count(&r2));
        assert!(c2 > c1, "{c1} -> {c2}");
    }
}
