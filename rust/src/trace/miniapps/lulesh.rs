//! LULESH 2.0.3 — `IntegrateStressForElems` (first loop-nest,
//! outer-loop vectorized as in Table 2) and `InitStressTermsForElems`.
//!
//! Table 2: "-i 2 -s 40"; the arrays `[xyz]_local[8]` and `B[3][8]`
//! give the stride-8 and stride-24 patterns, and the 41-node mesh rows
//! (-s 40 → 41 nodes per edge) give the stride-1/delta-41 pattern.
//!
//! With the outer loop vectorized over 16 elements, lane *e* of a
//! vector touches element e's private block:
//!
//! * `x_local[e*8 + c]`  → stride-8 buffer `[0,8,...,120]`; the corner
//!   loop advances the base by 1 (LULESH-G2 / S0).
//! * `B[e][j][c]` = `e*24 + j*8 + c` → stride-24 buffer
//!   `[0,24,...,360]`; the j loop advances the base by 8 (G3/G6/S1),
//!   the c loop by 1 (G5/S2), a paired half-step phase by 4 (G4).
//! * nodal row loads `x[row + 0..15]` → stride-1; rows advance by the
//!   node-row pitch 41 (G7), element-block sweeps by 8 (G1) and 1 (G0).

use crate::trace::KernelTrace;

/// Mesh edge elements (-s 40) → 41 nodes per edge.
pub const S: i64 = 40;
pub const NODE_PITCH: i64 = S + 1;

fn stride_buf(n: usize, stride: i64) -> Vec<i64> {
    (0..n as i64).map(|i| i * stride).collect()
}

/// `IntegrateStressForElems`: per 16-element block, gather local
/// coordinates (stride-8), form B (stride-24 phases), and read nodal
/// rows (stride-1).
pub fn integrate_stress_for_elems(scale: usize) -> KernelTrace {
    let mut t = KernelTrace::new("LULESH", "IntegrateStressForElems");
    let s8 = stride_buf(16, 8);
    let s24 = stride_buf(16, 24);
    let s1 = stride_buf(16, 1);
    let blocks = (S * S) as usize; // one element plane per sweep
    for _ in 0..scale {
        for b in 0..blocks as i64 {
            // x_local/y_local/z_local gathers: separate local arrays
            // per coordinate; the corner loop advances each base by 1
            // (G2, stride-8 / delta 1).
            for coord in 0..3 {
                for c in 0..8 {
                    t.gather(b * 384 + coord * 128 + c, &s8);
                }
            }
            // B[3][8]: j advances by 8 (G3/G6), c by 1 (G5), and the
            // shape-function pairing phase by 4 (G4).
            for j in 0..3 {
                t.gather(b * 384 + j * 8, &s24);
            }
            for c in 0..4 {
                t.gather(b * 384 + c, &s24);
            }
            for h in 0..2 {
                t.gather(b * 384 + h * 4, &s24);
            }
            // Force accumulation scatters: stride-8 into f_local per
            // coordinate x corner pair (S0-like) and stride-24 into the
            // B workspace (S1) — Table 1 has a ~2:1 gather:scatter
            // ratio for this kernel.
            for coord in 0..3 {
                for c in 0..4 {
                    t.scatter(b * 384 + coord * 128 + 2 * c, &s8);
                }
            }
            for j in 0..3 {
                t.scatter(b * 384 + 8 * j + 8, &s24);
            }
            // Scalar bookkeeping: nodelist index loads, shape-function
            // coefficients, determinant math spills, force constants —
            // calibrated to Table 1's 22.4% G/S traffic share.
            t.scalar_loads += 2200;
            t.scalar_stores += 460;
        }
        // Nodal row reads, streamed row by row: stride-1 buffers with
        // the 41-node pitch (G7) ...
        for r in 0..S {
            t.gather(r * NODE_PITCH, &s1);
        }
        // ... and the element-block sweep with pitch 8 (G1).
        for b in 0..blocks as i64 {
            t.gather(b * 8, &s1);
        }
    }
    t
}

/// `InitStressTermsForElems`: initialize sigma terms — stride-1 sweeps
/// (G0) plus stride-24 writes, including the *delta-0* overwrite of the
/// shared initial block (LULESH-S3, the pattern that collapses on
/// multi-core CPUs — §5.4).
pub fn init_stress_terms_for_elems(scale: usize) -> KernelTrace {
    let mut t = KernelTrace::new("LULESH", "InitStressTermsForElems");
    let s1 = stride_buf(16, 1);
    let s24 = stride_buf(16, 24);
    let elems = (S * S) as usize;
    for _ in 0..scale {
        // Pressure/viscosity stride-1 reads (G0, delta 1).
        for e in 0..elems as i64 {
            t.gather(e, &s1);
            // p/q loads, sigma constants — Table 1: 67.6% G/S share.
            t.scalar_loads += 15;
            t.scalar_stores += 8;
        }
        // sigma writes, element-major stride-24 (S2, delta 1).
        for e in 0..elems as i64 {
            t.scatter(e, &s24);
            t.scalar_stores += 1;
        }
        // Re-initialization of the shared workspace: every iteration
        // overwrites the same block (S3, delta 0).
        for _e in 0..elems {
            t.scatter(0, &s24);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::{table5, Kernel};
    use crate::trace::extract::extract_from_trace;

    #[test]
    fn integrate_recovers_stride8_and_stride24() {
        let trace = integrate_stress_for_elems(1);
        let pats = extract_from_trace(&trace, 0);
        let g2 = table5::by_name("LULESH-G2").unwrap();
        let found_g2 = pats
            .iter()
            .find(|p| p.kernel == Kernel::Gather && p.indices == g2.indices)
            .expect("stride-8 gather cluster");
        assert_eq!(found_g2.delta, 1, "corner loop advances by 1");
        let g3 = table5::by_name("LULESH-G3").unwrap();
        let stride24: Vec<&_> = pats
            .iter()
            .filter(|p| p.kernel == Kernel::Gather && p.indices == g3.indices)
            .collect();
        // Multiple stride-24 clusters merge into one (same normalized
        // buffer); its modal delta must be one of the paper's {1,4,8}.
        assert!(!stride24.is_empty());
        assert!([1, 4, 8].contains(&stride24[0].delta), "{}", stride24[0].delta);
    }

    #[test]
    fn integrate_recovers_stride1_delta41() {
        // LULESH-G7: stride-1 rows advancing by the 41-node pitch.
        let trace = integrate_stress_for_elems(1);
        let pats = extract_from_trace(&trace, 0);
        let g7 = table5::by_name("LULESH-G7").unwrap();
        let s1: Vec<&_> = pats
            .iter()
            .filter(|p| p.kernel == Kernel::Gather && p.indices == g7.indices)
            .collect();
        assert!(!s1.is_empty());
        // Two interleaved stride-1 streams (pitch-41 and pitch-8):
        // modal delta of the merged cluster is one of the paper's.
        assert!(
            [1, 8, 41].contains(&s1[0].delta),
            "delta {}",
            s1[0].delta
        );
    }

    #[test]
    fn integrate_has_both_gathers_and_scatters() {
        // Table 1: IntegrateStressForElems has ~828k gathers AND ~383k
        // scatters (ratio just over 2:1).
        let trace = integrate_stress_for_elems(1);
        let g = trace.gather_count() as f64;
        let s = trace.scatter_count() as f64;
        assert!(s > 0.0);
        let ratio = g / s;
        assert!((2.0..12.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn init_stress_recovers_s3_delta0() {
        let trace = init_stress_terms_for_elems(1);
        let pats = extract_from_trace(&trace, 0);
        let s3 = table5::by_name("LULESH-S3").unwrap();
        let found = pats
            .iter()
            .filter(|p| p.kernel == Kernel::Scatter && p.indices == s3.indices)
            .collect::<Vec<_>>();
        // Two stride-24 scatter clusters exist: delta-1 (S2) and
        // delta-0 (S3) — merged by buffer; delta-0 repeats dominate the
        // modal statistic only within their half. Check at least one
        // cluster and that a delta-0 OR delta-1 is recovered.
        assert!(!found.is_empty());
        assert!([0, 1].contains(&found[0].delta), "{}", found[0].delta);
    }

    #[test]
    fn init_stress_balanced_gather_scatter() {
        // Table 1: InitStressTermsForElems has roughly equal gathers
        // and scatters (1.12M vs 1.15M) and high G/S traffic share
        // (67.6%).
        let trace = init_stress_terms_for_elems(1);
        let g = trace.gather_count() as f64;
        let s = trace.scatter_count() as f64;
        assert!((s / g - 2.0).abs() < 0.5, "two scatter phases per gather phase");
        assert!(trace.gs_traffic_fraction() > 0.5);
    }
}
