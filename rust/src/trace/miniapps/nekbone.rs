//! Nekbone 2.3.5 — the `ax_e` kernel (Table 2: ldim 3, 16³ points per
//! element, 32 elements; "first loop in ax ... contains the observed
//! stride-6").
//!
//! The spectral local-gradient loop reads `u` along the slowest
//! dimension while accumulating three derivative components — the
//! vectorized lanes land 6 elements apart (2 × ldim), giving Table 5's
//! `[0, 6, ..., 90]` buffer. The base advances by 3 inside the
//! derivative triple (NEKBONE-G0) and by 8 per unrolled row pair
//! across the CG iteration (G1/G2).

use crate::trace::KernelTrace;

/// Points per element edge (nx0 = 16).
pub const NX: i64 = 16;
/// Elements per rank (iel0 = 32).
pub const NELT: i64 = 32;

/// `ax_e` — matrix-free Helmholtz operator application.
pub fn ax_e(scale: usize) -> KernelTrace {
    let mut t = KernelTrace::new("Nekbone", "ax_e");
    let s6: Vec<i64> = (0..16).map(|i| i * 6).collect();
    let rows = NX * NX / 4; // gradient rows per element sweep (scaled)
    for _ in 0..scale {
        for e in 0..NELT {
            let ebase = e * NX * NX * NX;
            // Derivative triple: base advances by ldim = 3 (G0).
            for r in 0..rows {
                for d in 0..3 {
                    t.gather(ebase + r * 96 + d * 3, &s6);
                }
            }
            // Unrolled row-pair sweep: base advances by 8 (G1/G2 — the
            // paper lists the same buffer twice, once per loop copy).
            for r in 0..rows {
                t.gather(ebase + r * 8, &s6);
            }
            // Scalar: D-matrix loads (16 basis coefficients per
            // gradient row across the four gathers) and result stores —
            // calibrated to Table 1's ~33% G/S traffic share.
            t.scalar_loads += (rows * 112) as u64;
            t.scalar_stores += (rows * 16) as u64;
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::{table5, PatternClass};
    use crate::trace::extract::extract_from_trace;

    #[test]
    fn recovers_stride6_buffer() {
        let trace = ax_e(1);
        let pats = extract_from_trace(&trace, 0);
        let g0 = table5::by_name("NEKBONE-G0").unwrap();
        assert_eq!(pats[0].indices, g0.indices, "stride-6 buffer");
        assert_eq!(pats[0].class, PatternClass::UniformStride(6));
        // The merged cluster's modal delta is 3 (the derivative triple
        // dominates 3:1 over the row-pair sweep).
        assert_eq!(pats[0].delta, 3);
    }

    #[test]
    fn gathers_only() {
        // Table 1: ax_e has 2.9M gathers, 0 scatters.
        let trace = ax_e(1);
        assert!(trace.gather_count() > 0);
        assert_eq!(trace.scatter_count(), 0);
    }

    #[test]
    fn traffic_fraction_ballpark() {
        // Table 1: 33.3% of the kernel's traffic is G/S.
        let f = ax_e(1).gs_traffic_fraction();
        assert!((0.2..0.6).contains(&f), "fraction {f}");
    }
}
