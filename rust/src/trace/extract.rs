//! The pattern extractor: turn a raw G/S instruction trace into ranked
//! (index-buffer, delta) proxy patterns — the paper's §2 post-
//! processing, which produced Table 5.
//!
//! Algorithm:
//! 1. Normalize each record's offset vector (min lane offset = 0,
//!    preserving lane order) and fold the shift into the base.
//! 2. Cluster records by (kernel, normalized offsets).
//! 3. Within a cluster, the *delta* is the modal difference between
//!    consecutive normalized bases.
//! 4. Rank clusters by data moved; classify each buffer with the
//!    paper's taxonomy.

use std::collections::HashMap;

use super::{GsRecord, KernelTrace};
use crate::pattern::{classify_indices, Kernel, Pattern, PatternClass};

/// One extracted proxy pattern (a Table 5 row candidate).
#[derive(Debug, Clone)]
pub struct ExtractedPattern {
    pub kernel: Kernel,
    /// Normalized index buffer, lane order preserved.
    pub indices: Vec<i64>,
    /// Modal base-to-base distance.
    pub delta: i64,
    /// Instructions in the cluster.
    pub occurrences: u64,
    /// Bytes moved by the cluster.
    pub bytes: u64,
    pub class: PatternClass,
}

impl ExtractedPattern {
    /// Materialize as a runnable Spatter pattern.
    pub fn to_pattern(&self, name: &str, count: usize) -> Pattern {
        Pattern::from_indices(name, self.indices.clone())
            .with_delta(self.delta.max(0))
            .with_count(count)
    }
}

/// Extract ranked patterns from a trace. `top` limits the output
/// (0 = all). Clusters are ranked by bytes moved, descending.
pub fn extract_patterns(records: &[GsRecord], top: usize) -> Vec<ExtractedPattern> {
    // Cluster by (kernel, normalized offsets); keep bases in trace order.
    #[allow(clippy::type_complexity)]
    let mut clusters: HashMap<(Kernel, Vec<i64>), Vec<i64>> = HashMap::new();
    let mut order: Vec<(Kernel, Vec<i64>)> = Vec::new();
    for r in records {
        let (base, norm) = r.normalized();
        let key = (r.kernel, norm);
        match clusters.get_mut(&key) {
            Some(bases) => bases.push(base),
            None => {
                order.push(key.clone());
                clusters.insert(key, vec![base]);
            }
        }
    }

    let mut out: Vec<ExtractedPattern> = order
        .into_iter()
        .map(|key| {
            let bases = &clusters[&key];
            let (kernel, indices) = key;
            let delta = modal_delta(bases);
            let occurrences = bases.len() as u64;
            let bytes = occurrences * indices.len() as u64 * 8;
            let class = classify_indices(&indices);
            ExtractedPattern {
                kernel,
                indices,
                delta,
                occurrences,
                bytes,
                class,
            }
        })
        .collect();
    out.sort_by(|a, b| b.bytes.cmp(&a.bytes));
    if top > 0 {
        out.truncate(top);
    }
    out
}

/// Extract from a whole kernel trace.
pub fn extract_from_trace(trace: &KernelTrace, top: usize) -> Vec<ExtractedPattern> {
    extract_patterns(&trace.records, top)
}

/// The most common difference between consecutive bases (0 for a
/// single-record cluster).
fn modal_delta(bases: &[i64]) -> i64 {
    if bases.len() < 2 {
        return 0;
    }
    let mut counts: HashMap<i64, u64> = HashMap::new();
    for w in bases.windows(2) {
        *counts.entry(w[1] - w[0]).or_insert(0) += 1;
    }
    counts
        .into_iter()
        .max_by_key(|&(delta, n)| (n, -delta))
        .map(|(d, _)| d)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gather(base: i64, offsets: &[i64]) -> GsRecord {
        GsRecord {
            kernel: Kernel::Gather,
            base,
            offsets: offsets.to_vec(),
        }
    }

    #[test]
    fn single_uniform_cluster() {
        // stride-4 gathers marching with delta 2
        let offsets: Vec<i64> = (0..16).map(|j| j * 4).collect();
        let records: Vec<GsRecord> =
            (0..100).map(|i| gather(2 * i, &offsets)).collect();
        let pats = extract_patterns(&records, 0);
        assert_eq!(pats.len(), 1);
        let p = &pats[0];
        assert_eq!(p.indices, offsets);
        assert_eq!(p.delta, 2);
        assert_eq!(p.occurrences, 100);
        assert_eq!(p.class, PatternClass::UniformStride(4));
    }

    #[test]
    fn normalization_folds_into_base() {
        // offsets [8, 12, 16] at base b == [0, 4, 8] at base b+8.
        let records: Vec<GsRecord> =
            (0..10).map(|i| gather(3 * i, &[8, 12, 16])).collect();
        let pats = extract_patterns(&records, 0);
        assert_eq!(pats[0].indices, vec![0, 4, 8]);
        assert_eq!(pats[0].delta, 3);
    }

    #[test]
    fn lane_order_is_preserved() {
        // PENNANT-style quad order [4, 8, 12, 0] must not be sorted.
        let records: Vec<GsRecord> =
            (0..10).map(|i| gather(4 * i, &[4, 8, 12, 0])).collect();
        let pats = extract_patterns(&records, 0);
        assert_eq!(pats[0].indices, vec![4, 8, 12, 0]);
        assert_eq!(pats[0].class, PatternClass::Complex);
    }

    #[test]
    fn clusters_ranked_by_bytes() {
        let mut records = Vec::new();
        for i in 0..5 {
            records.push(gather(i, &[0, 1])); // 5 * 16 B
        }
        for i in 0..100 {
            records.push(gather(i, &[0, 2, 4, 6])); // 100 * 32 B
        }
        let pats = extract_patterns(&records, 0);
        assert_eq!(pats.len(), 2);
        assert_eq!(pats[0].indices, vec![0, 2, 4, 6]);
        assert_eq!(pats[1].indices, vec![0, 1]);
        // top-1 truncation
        assert_eq!(extract_patterns(&records, 1).len(), 1);
    }

    #[test]
    fn gather_and_scatter_do_not_merge() {
        let mut records = vec![gather(0, &[0, 1])];
        records.push(GsRecord {
            kernel: Kernel::Scatter,
            base: 0,
            offsets: vec![0, 1],
        });
        let pats = extract_patterns(&records, 0);
        assert_eq!(pats.len(), 2);
    }

    #[test]
    fn modal_delta_picks_majority() {
        // bases mostly advance by 4, with one irregular jump
        assert_eq!(modal_delta(&[0, 4, 8, 12, 100, 104, 108]), 4);
        assert_eq!(modal_delta(&[7]), 0);
        assert_eq!(modal_delta(&[]), 0);
    }

    #[test]
    fn broadcast_cluster_classified() {
        let b: Vec<i64> = vec![0, 0, 0, 0, 1, 1, 1, 1];
        let records: Vec<GsRecord> = (0..20).map(|i| gather(4 * i, &b)).collect();
        let pats = extract_patterns(&records, 0);
        assert_eq!(pats[0].class, PatternClass::Broadcast);
        assert_eq!(pats[0].delta, 4);
    }

    #[test]
    fn to_pattern_roundtrip() {
        let records: Vec<GsRecord> =
            (0..10).map(|i| gather(8 * i, &[0, 1, 2, 3])).collect();
        let pats = extract_patterns(&records, 0);
        let p = pats[0].to_pattern("extracted", 100);
        assert_eq!(p.indices, vec![0, 1, 2, 3]);
        assert_eq!(p.delta, 8);
        assert_eq!(p.count, 100);
        p.validate().unwrap();
    }
}
