//! Trace capture + gather/scatter pattern extraction — the paper's §2
//! methodology, rebuilt end-to-end.
//!
//! The paper ran DoE mini-apps through an instrumented (closed-source)
//! SVE-1024 QEMU and post-processed the G/S instruction stream into
//! (index-buffer, delta) proxy patterns. Here:
//!
//! * [`miniapps`] — emulators of the hot kernels of AMG, LULESH,
//!   Nekbone, and PENNANT at the paper's Table 2 problem shapes
//!   (scaled), emitting the same SVE-style G/S records (16 × 64-bit
//!   lanes) plus scalar load/store counts.
//! * [`extract`] — the pattern extractor: cluster records by their
//!   normalized offset vector, recover the per-cluster delta from
//!   consecutive base addresses, rank by data motion.
//!
//! Ground truth: the paper's own Table 5. `suite::table1` runs the
//! emulators through the extractor and checks the recovered patterns
//! against `pattern::table5`.

pub mod extract;
pub mod miniapps;

pub use extract::{extract_patterns, ExtractedPattern};

use crate::pattern::Kernel;

/// SVE vector length in 64-bit lanes (1024-bit vectors, paper §2).
pub const SVE_LANES: usize = 16;

/// One gather/scatter instruction record from a trace: a base address
/// (in elements) and the per-lane offset vector.
#[derive(Debug, Clone, PartialEq)]
pub struct GsRecord {
    pub kernel: Kernel,
    /// Base element address of the instruction.
    pub base: i64,
    /// Per-lane element offsets (length == SVE_LANES for full vectors).
    pub offsets: Vec<i64>,
}

impl GsRecord {
    /// Offsets normalized so the minimum is zero, preserving lane
    /// order (Spatter index buffers are zero-based).
    pub fn normalized(&self) -> (i64, Vec<i64>) {
        let min = self.offsets.iter().copied().min().unwrap_or(0);
        (
            self.base + min,
            self.offsets.iter().map(|o| o - min).collect(),
        )
    }
}

/// The trace of one application kernel (one Table 1 row).
#[derive(Debug, Clone)]
pub struct KernelTrace {
    /// Application name ("AMG", "LULESH", ...).
    pub app: &'static str,
    /// Kernel name as in Table 1 (e.g. "hypre_CSRMatrixMatvecOutOfPlace").
    pub kernel: &'static str,
    pub records: Vec<GsRecord>,
    /// Scalar (non-G/S) loads and stores, for the Table 1 G/S-traffic
    /// percentage column. Counted as 64-bit like the paper does.
    pub scalar_loads: u64,
    pub scalar_stores: u64,
}

impl KernelTrace {
    pub fn new(app: &'static str, kernel: &'static str) -> KernelTrace {
        KernelTrace {
            app,
            kernel,
            records: Vec::new(),
            scalar_loads: 0,
            scalar_stores: 0,
        }
    }

    /// Emit one gather record.
    pub fn gather(&mut self, base: i64, offsets: &[i64]) {
        self.records.push(GsRecord {
            kernel: Kernel::Gather,
            base,
            offsets: offsets.to_vec(),
        });
    }

    /// Emit one scatter record.
    pub fn scatter(&mut self, base: i64, offsets: &[i64]) {
        self.records.push(GsRecord {
            kernel: Kernel::Scatter,
            base,
            offsets: offsets.to_vec(),
        });
    }

    /// Table 1 columns.
    pub fn gather_count(&self) -> u64 {
        self.records
            .iter()
            .filter(|r| r.kernel == Kernel::Gather)
            .count() as u64
    }

    pub fn scatter_count(&self) -> u64 {
        self.records
            .iter()
            .filter(|r| r.kernel == Kernel::Scatter)
            .count() as u64
    }

    /// Bytes moved by G/S instructions.
    pub fn gs_bytes(&self) -> u64 {
        self.records.iter().map(|r| r.offsets.len() as u64 * 8).sum()
    }

    /// G/S share of all load/store traffic (Table 1 "G/S MB (%)").
    pub fn gs_traffic_fraction(&self) -> f64 {
        let gs = self.gs_bytes() as f64;
        let total = gs + (self.scalar_loads + self.scalar_stores) as f64 * 8.0;
        if total == 0.0 {
            0.0
        } else {
            gs / total
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_normalization() {
        let r = GsRecord {
            kernel: Kernel::Gather,
            base: 100,
            offsets: vec![5, 3, 9, 3],
        };
        let (base, norm) = r.normalized();
        assert_eq!(base, 103);
        assert_eq!(norm, vec![2, 0, 6, 0]);
    }

    #[test]
    fn trace_accounting() {
        let mut t = KernelTrace::new("TEST", "k");
        t.gather(0, &[0, 1, 2, 3]);
        t.gather(4, &[0, 1, 2, 3]);
        t.scatter(0, &[0, 8]);
        t.scalar_loads = 10;
        t.scalar_stores = 2;
        assert_eq!(t.gather_count(), 2);
        assert_eq!(t.scatter_count(), 1);
        assert_eq!(t.gs_bytes(), (4 + 4 + 2) * 8);
        let frac = t.gs_traffic_fraction();
        let want = 80.0 / (80.0 + 96.0);
        assert!((frac - want).abs() < 1e-12);
    }

    #[test]
    fn empty_trace_fraction_is_zero() {
        let t = KernelTrace::new("TEST", "k");
        assert_eq!(t.gs_traffic_fraction(), 0.0);
    }
}
