//! # Spatter — a tool for evaluating gather/scatter performance
//!
//! Rust + JAX + Pallas reproduction of *“Spatter: A Tool for Evaluating
//! Gather / Scatter Performance”* (Lavin et al., 2018).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L1** — Pallas gather/scatter kernels (`python/compile/kernels/`),
//!   AOT-lowered to HLO text at build time.
//! * **L2** — JAX run graphs (`python/compile/model.py`), one artifact
//!   per (kernel × geometry) variant.
//! * **L3** — this crate: the Spatter pattern language, run protocol,
//!   statistics, backends (memory-hierarchy simulators for the paper's
//!   ten platforms plus real execution through PJRT-CPU), the trace
//!   analysis pipeline for mini-app pattern extraction, and the
//!   experiment suite that regenerates every table and figure in the
//!   paper's evaluation.
//!
//! Python never runs at benchmark time: `make artifacts` is the only
//! Python entry point, and the `spatter` binary is self-contained after
//! artifacts exist.
//!
//! ## Quick tour
//!
//! ```no_run
//! use spatter::pattern::{Pattern, Kernel};
//! use spatter::platforms;
//! use spatter::backends::{Backend, OpenMpSim};
//!
//! // STREAM-like run: ./spatter -k Gather -p UNIFORM:8:1 -d 8 -l N
//! let pat = Pattern::parse("UNIFORM:8:1").unwrap()
//!     .with_delta(8).with_count(1 << 20);
//! let skx = platforms::by_name("skx").unwrap();
//! let mut backend = OpenMpSim::new(&skx);
//! let res = backend.run(&pat, Kernel::Gather).unwrap();
//! println!("{:.1} GB/s", res.bandwidth_gbs());
//! ```

pub mod backends;
pub mod cli;
pub mod coordinator;
pub mod error;
pub mod json;
pub mod pattern;
pub mod platforms;
pub mod prop;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod suite;
pub mod trace;

pub use error::{Error, Result};
