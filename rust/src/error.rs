//! Crate-wide error type.
//!
//! Hand-rolled (no `thiserror` in the offline vendor set); a small enum
//! keeps failure modes explicit for library users.

use std::fmt;

/// All the ways a Spatter operation can fail.
#[derive(Debug)]
pub enum Error {
    /// Malformed pattern spec (`UNIFORM:8:x`, bad MS1 params, ...).
    PatternParse(String),
    /// Malformed CLI invocation.
    Cli(String),
    /// JSON syntax or schema error in a config / manifest file.
    Json(String),
    /// Run configuration that cannot be executed (zero count, address
    /// overflow, source buffer too small, ...).
    Config(String),
    /// Artifact discovery / PJRT runtime failure.
    Runtime(String),
    /// Platform registry miss.
    UnknownPlatform(String),
    /// Underlying I/O failure.
    Io(std::io::Error),
    /// Error bubbled up from the `xla` crate.
    Xla(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::PatternParse(m) => write!(f, "pattern parse error: {m}"),
            Error::Cli(m) => write!(f, "cli error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::UnknownPlatform(m) => write!(f, "unknown platform: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_are_prefixed() {
        let cases: Vec<(Error, &str)> = vec![
            (Error::PatternParse("x".into()), "pattern parse error"),
            (Error::Cli("x".into()), "cli error"),
            (Error::Json("x".into()), "json error"),
            (Error::Config("x".into()), "config error"),
            (Error::Runtime("x".into()), "runtime error"),
            (Error::UnknownPlatform("x".into()), "unknown platform"),
            (Error::Xla("x".into()), "xla error"),
        ];
        for (e, prefix) in cases {
            assert!(e.to_string().starts_with(prefix), "{e}");
        }
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
    }
}
