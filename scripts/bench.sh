#!/usr/bin/env bash
# Perf-trajectory runner: times the ustride fast sweep and the
# LULESH-S3 delta-0 proxy, each A/B'd twice — loop closure on vs off,
# and the batch-compiled access plan on vs off (the plan-* records) —
# plus the scheduler/memo/stream campaign legs, the dram-bank
# pow2-vs-odd conflict cell, and the simd-regime scalar-vs-native
# vectorization ladder, and records the wall-clock numbers in
# BENCH_sim.json (repo root by default, or $1).
#
# Usage: scripts/bench.sh [output.json]
set -euo pipefail
cd "$(dirname "$0")/.."
out="${1:-$PWD/BENCH_sim.json}"
case "$out" in
  /*) ;;
  *) out="$PWD/$out" ;;
esac
BENCH_SIM_JSON="$out" cargo bench --bench sweep
echo "bench record: $out"
