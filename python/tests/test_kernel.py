"""Kernel-vs-oracle correctness: the CORE numeric signal of the build.

The Pallas gather/scatter kernels must match the pure-jnp oracles in
ref.py for every geometry the tool can feed them.  Hypothesis sweeps
shapes / dtypes / deltas / index contents; directed tests pin the
paper's specific pattern classes (uniform stride, broadcast, MS1,
delta-0 scatter, Laplacian-style irregular offsets).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gather as kg
from compile.kernels import ref
from compile.kernels import scatter as ks

jax.config.update("jax_enable_x64", True)


def _src(n, dtype=jnp.float64, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(n), dtype)


# ---------------------------------------------------------------------------
# Directed gather tests: the paper's pattern classes
# ---------------------------------------------------------------------------

class TestGatherDirected:
    def test_stream_like_stride1(self):
        # UNIFORM:8:1 with delta 8 == STREAM copy read (paper §3.4).
        src = _src(4096)
        idx = jnp.arange(8, dtype=jnp.int32)
        out = kg.gather(src, idx, 8, 64)
        np.testing.assert_array_equal(out, ref.gather(src, idx, 8, 64))
        # stride-1/delta-V gather is exactly the src prefix reshaped
        np.testing.assert_array_equal(out, src[: 64 * 8].reshape(64, 8))

    @pytest.mark.parametrize("stride", [1, 2, 4, 8, 16, 32, 64, 128])
    def test_uniform_stride_sweep(self, stride):
        # Fig 3's sweep: UNIFORM:8:stride, delta 8*stride.
        v, count = 8, 32
        n = count * 8 * stride + v * stride + 1
        src = _src(n)
        idx = jnp.arange(v, dtype=jnp.int32) * stride
        out = kg.gather(src, idx, 8 * stride, count)
        np.testing.assert_array_equal(
            out, ref.gather(src, idx, 8 * stride, count))

    def test_broadcast_pattern(self):
        # PENNANT-G4: [0,0,0,0,1,1,1,1,2,2,2,2,3,3,3,3], delta 4.
        idx = jnp.asarray([0] * 4 + [1] * 4 + [2] * 4 + [3] * 4, jnp.int32)
        src = _src(1024)
        out = kg.gather(src, idx, 4, 16)
        np.testing.assert_array_equal(out, ref.gather(src, idx, 4, 16))
        # broadcast means 4 identical columns per group
        np.testing.assert_array_equal(out[:, 0], out[:, 3])

    def test_mostly_stride1_pattern(self):
        # MS1:8:4:20 -> [0,1,2,3,23,24,25,26] (paper §3.3.2).
        idx = jnp.asarray([0, 1, 2, 3, 23, 24, 25, 26], jnp.int32)
        src = _src(2048)
        out = kg.gather(src, idx, 2, 16)
        np.testing.assert_array_equal(out, ref.gather(src, idx, 2, 16))

    def test_laplacian_pattern(self):
        # LAPLACIAN:2:1:100 5-point stencil [0,99,100,101,200] (0-based).
        idx = jnp.asarray([0, 99, 100, 101, 200], jnp.int32)
        src = _src(100 * 100 + 256)
        out = kg.gather(src, idx, 1, 64)
        np.testing.assert_array_equal(out, ref.gather(src, idx, 1, 64))

    def test_delta_zero_gather(self):
        # delta 0: every gather reads the same addresses.
        idx = jnp.asarray([5, 3, 1, 7], jnp.int32)
        src = _src(64)
        out = kg.gather(src, idx, 0, 16)
        np.testing.assert_array_equal(out, ref.gather(src, idx, 0, 16))
        np.testing.assert_array_equal(out[0], out[15])

    def test_table5_amg_pattern(self):
        # AMG-G0, a "mostly stride-1" 27-ish point pattern.
        idx = jnp.asarray(
            [1333, 0, 1, 36, 37, 72, 73, 1296, 1297, 1332, 1368, 1369,
             2592, 2593, 2628, 2629], jnp.int32)
        src = _src(8192)
        out = kg.gather(src, idx, 1, 32)
        np.testing.assert_array_equal(out, ref.gather(src, idx, 1, 32))

    def test_explicit_tile_override(self):
        src = _src(512)
        idx = jnp.arange(16, dtype=jnp.int32)
        a = kg.gather(src, idx, 16, 24, tile_i=8)
        b = kg.gather(src, idx, 16, 24, tile_i=1)
        np.testing.assert_array_equal(a, b)

    def test_bad_tile_raises(self):
        src = _src(64)
        idx = jnp.arange(4, dtype=jnp.int32)
        with pytest.raises(ValueError):
            kg.gather(src, idx, 1, 10, tile_i=4)

    def test_f32_dtype(self):
        src = _src(256, jnp.float32)
        idx = jnp.asarray([0, 3, 9, 1], jnp.int32)
        out = kg.gather(src, idx, 2, 32)
        assert out.dtype == jnp.float32
        np.testing.assert_array_equal(out, ref.gather(src, idx, 2, 32))

    def test_checksum_matches_sum(self):
        src = _src(512)
        idx = jnp.arange(8, dtype=jnp.int32)
        c = kg.gather_checksum(src, idx, 8, 32)
        r = ref.gather_checksum(src, idx, 8, 32)
        np.testing.assert_allclose(float(c), float(r), rtol=1e-12)


# ---------------------------------------------------------------------------
# Directed scatter tests
# ---------------------------------------------------------------------------

class TestScatterDirected:
    def test_stride1_scatter_is_copy(self):
        v, count = 8, 32
        vals = _src(count * v).reshape(count, v)
        idx = jnp.arange(v, dtype=jnp.int32)
        dst = jnp.zeros(count * v, jnp.float64)
        out = ks.scatter(vals, idx, v, dst, count)
        np.testing.assert_array_equal(out, vals.reshape(-1))

    @pytest.mark.parametrize("stride", [1, 2, 4, 8, 24])
    def test_uniform_stride_scatter(self, stride):
        # LULESH-S0/S1-like uniform stride scatters.
        v, count = 8, 16
        n = count * 8 * stride + v * stride + 8
        vals = _src(count * v, seed=3).reshape(count, v)
        idx = jnp.arange(v, dtype=jnp.int32) * stride
        dst = jnp.full(n, -1.0, jnp.float64)
        out = ks.scatter(vals, idx, 8 * stride, dst, count)
        expect = ref.scatter(vals, idx, 8 * stride, dst, count)
        np.testing.assert_array_equal(out, expect)

    def test_delta_zero_scatter_envelope(self):
        # LULESH-S3: scatter with delta 0 — every iteration overwrites
        # the same slots; result must be one of the written values.
        v, count = 8, 16
        vals = _src(count * v, seed=5).reshape(count, v)
        idx = jnp.arange(v, dtype=jnp.int32) * 3
        dst = jnp.zeros(64, jnp.float64)
        out = np.asarray(ks.scatter(vals, idx, 0, dst, count))
        lo, hi = ref.scatter_candidates(vals, idx, 0, dst, count)
        assert (out >= lo - 1e-12).all() and (out <= hi + 1e-12).all()

    def test_untouched_slots_keep_seed(self):
        v, count = 4, 8
        vals = jnp.ones((count, v), jnp.float64)
        idx = jnp.arange(v, dtype=jnp.int32) * 2  # only even slots
        dst = jnp.full(128, 7.0, jnp.float64)
        out = np.asarray(ks.scatter(vals, idx, 8, dst, count))
        # odd slots within the written range keep the seed
        assert (out[1:64:2] == 7.0).all()
        assert (out[64:] == 7.0).all()

    def test_scatter_then_gather_roundtrip(self):
        # gather(scatter(x)) == x when addresses are disjoint.
        v, count = 8, 16
        vals = _src(count * v, seed=9).reshape(count, v)
        idx = jnp.arange(v, dtype=jnp.int32)
        dst = jnp.zeros(count * v, jnp.float64)
        scattered = ks.scatter(vals, idx, v, dst, count)
        back = kg.gather(scattered, idx, v, count)
        np.testing.assert_array_equal(back, vals)


# ---------------------------------------------------------------------------
# Hypothesis sweeps
# ---------------------------------------------------------------------------

@st.composite
def gather_cases(draw):
    v = draw(st.integers(1, 32))
    count = draw(st.integers(1, 64))
    delta = draw(st.integers(0, 16))
    idx = draw(st.lists(st.integers(0, 255), min_size=v, max_size=v))
    dtype = draw(st.sampled_from(["float64", "float32", "int32"]))
    return v, count, delta, idx, dtype


@settings(max_examples=40, deadline=None)
@given(gather_cases())
def test_gather_matches_ref_hypothesis(case):
    v, count, delta, idx, dtype = case
    n = count * delta + 256 + 1
    rng = np.random.default_rng(v * 1000 + count)
    if dtype == "int32":
        src = jnp.asarray(rng.integers(-1000, 1000, n), jnp.int32)
    else:
        src = jnp.asarray(rng.standard_normal(n), dtype)
    idx = jnp.asarray(idx, jnp.int32)
    out = kg.gather(src, idx, delta, count)
    np.testing.assert_array_equal(out, ref.gather(src, idx, delta, count))


@st.composite
def scatter_cases(draw):
    v = draw(st.integers(1, 16))
    count = draw(st.integers(1, 32))
    # distinct index-buffer entries + delta >= v*max_gap guarantees
    # address disjointness across iterations, so the result is unique
    idx = draw(st.lists(st.integers(0, 63), min_size=v, max_size=v,
                        unique=True))
    return v, count, sorted(idx)


@settings(max_examples=30, deadline=None)
@given(scatter_cases())
def test_scatter_disjoint_matches_ref_hypothesis(case):
    v, count, idx = case
    delta = 64  # > max idx: no cross-iteration overlap
    n = count * delta + 64 + 1
    rng = np.random.default_rng(count * 77 + v)
    vals = jnp.asarray(rng.standard_normal((count, v)), jnp.float64)
    idxa = jnp.asarray(idx, jnp.int32)
    dst = jnp.asarray(rng.standard_normal(n), jnp.float64)
    out = ks.scatter(vals, idxa, delta, dst, count)
    expect = ref.scatter(vals, idxa, delta, dst, count)
    np.testing.assert_array_equal(out, expect)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 16), st.integers(1, 32), st.integers(0, 8))
def test_gather_checksum_consistency(v, count, delta):
    n = count * delta + v + 1
    rng = np.random.default_rng(v + count + delta)
    src = jnp.asarray(rng.standard_normal(n), jnp.float64)
    idx = jnp.asarray(rng.integers(0, v + 1, v), jnp.int32)
    c = kg.gather_checksum(src, idx, delta, count)
    r = ref.gather_checksum(src, idx, delta, count)
    np.testing.assert_allclose(float(c), float(r), rtol=1e-10)
