"""L1 Pallas kernels for Spatter: gather and scatter inner loops.

The kernels implement the Spatter access-pattern semantics
(Algorithm 1 of the paper): for gather number ``i`` and index-buffer
slot ``j``::

    out[i, j] = src[delta * i + idx[j]]          # gather
    dst[delta * i + idx[j]] = vals[i, j]         # scatter

All kernels are lowered with ``interpret=True`` — the CPU PJRT plugin
cannot execute Mosaic custom-calls, and correctness (not TPU wallclock)
is the target on this testbed.  See DESIGN.md §Hardware-Adaptation for
the TPU mapping of the paper's CUDA shared-memory staging.
"""

from . import gather, ref, scatter  # noqa: F401
