"""Pallas scatter kernel (Spatter Algorithm 1, scatter direction).

``dst[delta*i + idx[j]] = vals[i, j]`` for i in [0, count), j in [0, V).

Grid/tile structure mirrors the gather kernel: the *count* dimension is
tiled by a BlockSpec; each grid step scatters one ``(TILE_I, V)`` tile of
values into the destination.  The destination block is the *whole*
buffer every step (indices are arbitrary), relying on the sequential
grid of interpret mode / TPU revisiting semantics — each step
read-modify-writes the accumulated destination.

Duplicate-index semantics: when two (i, j) slots produce the same
address, exactly one of the writes wins (XLA scatter, unordered) — the
same contract the paper's OpenMP/CUDA backends have, where concurrent
scatters to one address are racy.  The Rust coordinator and the tests
only rely on "one of the candidate values", matching the tool's
semantics (Spatter measures bandwidth, not scatter ordering).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gather import _pick_tile


def _scatter_kernel(idx_ref, delta_ref, vals_ref, dst_in_ref, out_ref,
                    *, tile_i: int):
    """One grid step: scatter a (tile_i, V) tile of values into dst.

    On step 0 the destination is seeded from dst_in; later steps
    read-modify-write the output block (whole-buffer mapping, sequential
    grid).
    """
    pid = pl.program_id(0)
    idx = idx_ref[...]
    delta = delta_ref[0]
    v = idx.shape[0]
    row = pid * tile_i + jax.lax.broadcasted_iota(jnp.int32, (tile_i, v), 0)
    addr = (row * delta + idx[None, :]).reshape(-1)
    vals = vals_ref[...].reshape(-1)

    @pl.when(pid == 0)
    def _seed():
        out_ref[...] = dst_in_ref[...]

    cur = out_ref[...]
    out_ref[...] = cur.at[addr].set(vals, mode="drop")


def scatter(vals, idx, delta, dst, count: int, *, tile_i: int | None = None):
    """Run the Spatter scatter pattern over an existing destination.

    Args:
      vals:  (count, V) values to scatter.
      idx:   (V,) int32 index buffer.
      delta: scalar int32.
      dst:   (N,) destination seed (returned array starts from this).
      count: number of scatters (static, == vals.shape[0]).

    Returns: (N,) destination after all scatters.
    """
    idx = jnp.asarray(idx, jnp.int32)
    delta = jnp.asarray(delta, jnp.int32).reshape((1,))
    v = idx.shape[0]
    if vals.shape != (count, v):
        raise ValueError(f"vals must be ({count}, {v}), got {vals.shape}")
    if tile_i is None:
        tile_i = _pick_tile(count)
    if count % tile_i != 0:
        raise ValueError(f"tile_i={tile_i} must divide count={count}")
    grid = count // tile_i
    kernel = functools.partial(_scatter_kernel, tile_i=tile_i)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(idx.shape, lambda i: (0,)),        # idx
            pl.BlockSpec((1,), lambda i: (0,)),             # delta
            pl.BlockSpec((tile_i, v), lambda i: (i, 0)),    # vals tile
            pl.BlockSpec(dst.shape, lambda i: (0,)),        # dst seed
        ],
        out_specs=pl.BlockSpec(dst.shape, lambda i: (0,)),  # whole dst
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        interpret=True,
    )(idx, delta, vals, dst)
