"""Pallas gather kernel (Spatter Algorithm 1, gather direction).

TPU adaptation of the paper's CUDA gather backend (DESIGN.md
§Hardware-Adaptation):

* The CUDA backend stages the 256-entry index buffer in *shared memory*
  once per thread block.  Here the index buffer is a small, fully-mapped
  input block — read once per grid step into registers/VMEM (the
  interpret-mode analogue of a scratch prefetch).
* The CUDA backend assigns one Spatter iteration (one gather of length V)
  to a thread block.  Here a BlockSpec tiles the *count* dimension: each
  grid step produces a ``(TILE_I, V)`` destination tile, so the
  HBM->VMEM schedule expressed by the BlockSpec plays the role of the
  threadblock schedule.
* There is no MXU work — gather is bandwidth-bound, zero FLOPs — so the
  kernel's only job is to keep address generation off the critical path
  (broadcasted-iota + one vector add) and stream tiles.

Semantics note: addresses are produced as ``delta*i + idx[j]``; the
caller is responsible for sizing ``src`` so all addresses are in bounds
(the Rust coordinator validates this).  Out-of-bounds indices clamp, per
XLA gather semantics, and are additionally exercised by tests.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_tile(count: int, preferred: int = 512) -> int:
    """Largest power-of-two tile <= preferred that divides count."""
    tile = 1
    t = 1
    while t <= count and t <= preferred:
        if count % t == 0:
            tile = t
        t *= 2
    return tile


def _gather_kernel(idx_ref, delta_ref, src_ref, out_ref, *, tile_i: int):
    """One grid step: gather a (tile_i, V) tile of the destination.

    idx_ref   : (V,)  int32 — the Spatter index buffer (scratch-staged)
    delta_ref : (1,)  int32 — delta between consecutive gathers
    src_ref   : (N,)  data  — the full source array (not blocked: the
                indices are arbitrary, so no sub-block of src is safe)
    out_ref   : (tile_i, V) data — this grid step's destination tile
    """
    pid = pl.program_id(0)
    idx = idx_ref[...]
    delta = delta_ref[0]
    v = idx.shape[0]
    # Global gather number for each row of the tile.
    row = pid * tile_i + jax.lax.broadcasted_iota(jnp.int32, (tile_i, v), 0)
    addr = row * delta + idx[None, :]
    src = src_ref[...]
    out_ref[...] = src[addr]


def gather(src, idx, delta, count: int, *, tile_i: int | None = None):
    """Run the Spatter gather pattern: out[i, j] = src[delta*i + idx[j]].

    Args:
      src:   (N,) source array.
      idx:   (V,) int32 index buffer.
      delta: scalar int32 (passed as shape-(1,) array or python int).
      count: number of gathers (static).
      tile_i: override the count-dimension tile (must divide count).

    Returns: (count, V) gathered array.
    """
    idx = jnp.asarray(idx, jnp.int32)
    delta = jnp.asarray(delta, jnp.int32).reshape((1,))
    v = idx.shape[0]
    if tile_i is None:
        tile_i = _pick_tile(count)
    if count % tile_i != 0:
        raise ValueError(f"tile_i={tile_i} must divide count={count}")
    grid = count // tile_i
    kernel = functools.partial(_gather_kernel, tile_i=tile_i)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(idx.shape, lambda i: (0,)),       # idx: whole buffer
            pl.BlockSpec((1,), lambda i: (0,)),            # delta scalar
            pl.BlockSpec(src.shape, lambda i: (0,)),       # src: whole array
        ],
        out_specs=pl.BlockSpec((tile_i, v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((count, v), src.dtype),
        interpret=True,
    )(idx, delta, src)


def gather_checksum(src, idx, delta, count: int):
    """Gather then reduce to a scalar — cheap numeric validation for the
    Rust driver (one f64 instead of a (count, V) readback)."""
    return jnp.sum(gather(src, idx, delta, count), dtype=jnp.float64)
