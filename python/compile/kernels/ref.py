"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground-truth semantics of Spatter's Algorithm 1, written
with plain jax.numpy indexing — no Pallas, no tiling.  Every Pallas
kernel must match these bit-for-bit (gather) or up to duplicate-write
resolution (scatter).  They are also AOT-lowered as the *throughput*
variants: XLA fuses them into a single tight gather/scatter loop with no
per-tile copy overhead, which is what the Rust driver times (see
DESIGN.md §2, real-execution substitution).
"""

import jax.numpy as jnp


def addresses(idx, delta, count: int):
    """The (count, V) address matrix addr[i, j] = delta*i + idx[j]."""
    idx = jnp.asarray(idx, jnp.int32)
    i = jnp.arange(count, dtype=jnp.int32)[:, None]
    delta = jnp.asarray(delta, jnp.int32).reshape(())
    return i * delta + idx[None, :]


def gather(src, idx, delta, count: int):
    """out[i, j] = src[delta*i + idx[j]]  (clamping OOB like XLA)."""
    return src[addresses(idx, delta, count)]


def gather_checksum(src, idx, delta, count: int):
    return jnp.sum(gather(src, idx, delta, count), dtype=jnp.float64)


def scatter(vals, idx, delta, dst, count: int):
    """dst[delta*i + idx[j]] = vals[i, j]; duplicate addresses resolve to
    one of the written values (XLA scatter, unordered)."""
    addr = addresses(idx, delta, count).reshape(-1)
    return dst.at[addr].set(vals.reshape(-1), mode="drop")


def scatter_candidates(vals, idx, delta, dst, count: int):
    """For testing duplicate-address scatters: per destination slot, the
    set of values that could legally end up there.  Returned as
    (min_candidate, max_candidate) arrays — any legal scatter result sits
    elementwise within the envelope."""
    import numpy as np

    addr = np.asarray(addresses(idx, delta, count)).reshape(-1)
    v = np.asarray(vals).reshape(-1)
    lo = np.array(dst, dtype=np.float64)
    hi = np.array(dst, dtype=np.float64)
    n = dst.shape[0]
    first = {}
    for a, val in zip(addr, v):
        if 0 <= a < n:
            if a in first:
                lo[a] = min(lo[a], val, first[a])
                hi[a] = max(hi[a], val, first[a])
            else:
                lo[a] = val
                hi[a] = val
                first[a] = val
    return lo, hi
