"""AOT-lower the L2 graphs to HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out ../artifacts

Emits one ``<name>.hlo.txt`` per (graph x geometry) variant plus a
``manifest.json`` the Rust runtime uses for artifact discovery (names,
shapes, dtypes, argument order).  Python never runs after this.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)

# Geometry grid. V mirrors the paper's tuning: 8/16 for CPU-style index
# buffers, 256 for the GPU-style buffer. count chosen so the throughput
# variants move ~10-100 MB per execution (bandwidth is size-invariant
# past warmup; DESIGN.md §4 scaling note).
GEOMETRIES = [
    # (V, count, N_src)
    (8, 4096, 1 << 22),
    (16, 4096, 1 << 22),
    (256, 1024, 1 << 22),
    # Small smoke geometry for fast integration tests.
    (8, 64, 1 << 12),
]

DTYPE = jnp.float64  # the paper's unit of data motion is the double


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True; the
    Rust side unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _gather_variants(v, count, n):
    src = jax.ShapeDtypeStruct((n,), DTYPE)
    idx = jax.ShapeDtypeStruct((v,), jnp.int32)
    delta = jax.ShapeDtypeStruct((1,), jnp.int32)
    for family, fn in [
        ("pallas", model.gather_pallas),
        ("ref", model.gather_ref),
    ]:
        name = f"gather_{family}_v{v}_c{count}_n{n}"
        yield name, functools.partial(fn, count=count), (src, idx, delta), {
            "kernel": "gather", "family": family,
            "v": v, "count": count, "n": n, "dtype": "f64",
            "args": [
                {"name": "src", "shape": [n], "dtype": "f64"},
                {"name": "idx", "shape": [v], "dtype": "s32"},
                {"name": "delta", "shape": [1], "dtype": "s32"},
            ],
            "out": {"shape": [count, v], "dtype": "f64"},
        }
    name = f"gather_checksum_ref_v{v}_c{count}_n{n}"
    yield name, functools.partial(model.gather_checksum_ref, count=count), (
        src, idx, delta), {
        "kernel": "gather_checksum", "family": "ref",
        "v": v, "count": count, "n": n, "dtype": "f64",
        "args": [
            {"name": "src", "shape": [n], "dtype": "f64"},
            {"name": "idx", "shape": [v], "dtype": "s32"},
            {"name": "delta", "shape": [1], "dtype": "s32"},
        ],
        "out": {"shape": [], "dtype": "f64"},
    }


def _scatter_variants(v, count, n):
    # §Perf: without buffer donation, PJRT copies the whole destination
    # every execution; a 32 MB dst costs ~30 ms and swamps the scatter
    # itself. The measured traffic is count*v writes, so a compact
    # destination preserves the benchmark while killing the copy.
    n = min(n, 1 << 18)
    vals = jax.ShapeDtypeStruct((count, v), DTYPE)
    idx = jax.ShapeDtypeStruct((v,), jnp.int32)
    delta = jax.ShapeDtypeStruct((1,), jnp.int32)
    dst = jax.ShapeDtypeStruct((n,), DTYPE)
    for family, fn in [
        ("pallas", model.scatter_pallas),
        ("ref", model.scatter_ref),
    ]:
        name = f"scatter_{family}_v{v}_c{count}_n{n}"
        yield name, functools.partial(fn, count=count), (
            vals, idx, delta, dst), {
            "kernel": "scatter", "family": family,
            "v": v, "count": count, "n": n, "dtype": "f64",
            "args": [
                {"name": "vals", "shape": [count, v], "dtype": "f64"},
                {"name": "idx", "shape": [v], "dtype": "s32"},
                {"name": "delta", "shape": [1], "dtype": "s32"},
                {"name": "dst", "shape": [n], "dtype": "f64"},
            ],
            "out": {"shape": [n], "dtype": "f64"},
        }
    name = f"scatter_checksum_ref_v{v}_c{count}_n{n}"
    yield name, functools.partial(model.scatter_checksum_ref, count=count), (
        vals, idx, delta, dst), {
        "kernel": "scatter_checksum", "family": "ref",
        "v": v, "count": count, "n": n, "dtype": "f64",
        "args": [
            {"name": "vals", "shape": [count, v], "dtype": "f64"},
            {"name": "idx", "shape": [v], "dtype": "s32"},
            {"name": "delta", "shape": [1], "dtype": "s32"},
            {"name": "dst", "shape": [n], "dtype": "f64"},
        ],
        "out": {"shape": [], "dtype": "f64"},
    }


def variants():
    for v, count, n in GEOMETRIES:
        yield from _gather_variants(v, count, n)
        yield from _scatter_variants(v, count, n)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--only", default=None,
                    help="substring filter on variant names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"format": "hlo-text", "variants": []}
    for name, fn, specs, meta in variants():
        if args.only and args.only not in name:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["name"] = name
        meta["file"] = f"{name}.hlo.txt"
        manifest["variants"].append(meta)
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['variants'])} variants")


if __name__ == "__main__":
    main()
