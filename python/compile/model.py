"""L2: the Spatter run as a jitted JAX graph, calling the L1 kernels.

Each public function here is one AOT artifact shape (see aot.py).  The
graph is pure dataflow: (src|vals, idx, delta) -> gathered tiles or
scattered destination.  Shapes (count, V, N) are static per artifact;
idx and delta are runtime *inputs*, so a single artifact serves every
pattern with the same geometry — the Rust coordinator picks the artifact
by geometry and feeds the pattern at run time.

Two families are lowered:

* ``*_pallas`` — routed through the L1 Pallas kernels (interpret=True).
  These validate the kernel-in-HLO path end to end.
* ``*_ref``   — the pure-jnp oracle.  XLA fuses these into one tight
  gather/scatter loop; the Rust driver times these for the
  real-execution bandwidth numbers (DESIGN.md §2).
"""

import jax.numpy as jnp

from .kernels import gather as k
from .kernels import ref
from .kernels import scatter as ks


# ---------------------------------------------------------------------------
# Gather graphs
# ---------------------------------------------------------------------------

def gather_pallas(src, idx, delta, *, count: int):
    """(N,) x (V,) x (1,) -> (count, V) via the Pallas kernel."""
    return k.gather(src, idx, delta, count)


def gather_ref(src, idx, delta, *, count: int):
    """Same contract, pure-jnp (XLA-fused throughput variant)."""
    return ref.gather(src, idx, delta, count)


def gather_checksum_pallas(src, idx, delta, *, count: int):
    """Gather + scalar reduce: cheap numeric validation readback."""
    return k.gather_checksum(src, idx, delta, count)


def gather_checksum_ref(src, idx, delta, *, count: int):
    return ref.gather_checksum(src, idx, delta, count)


# ---------------------------------------------------------------------------
# Scatter graphs
# ---------------------------------------------------------------------------

def scatter_pallas(vals, idx, delta, dst, *, count: int):
    """(count,V) x (V,) x (1,) x (N,) -> (N,) via the Pallas kernel."""
    return ks.scatter(vals, idx, delta, dst, count)


def scatter_ref(vals, idx, delta, dst, *, count: int):
    return ref.scatter(vals, idx, delta, dst, count)


def scatter_checksum_ref(vals, idx, delta, dst, *, count: int):
    """Scatter + scalar reduce of the destination."""
    return jnp.sum(ref.scatter(vals, idx, delta, dst, count),
                   dtype=jnp.float64)
