//! END-TO-END DRIVER: proves all three layers compose on a real
//! workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example full_suite
//! ```
//!
//! 1. **Real execution (L1+L2+runtime):** loads the AOT HLO artifacts
//!    (Pallas kernel + jnp-oracle graphs), validates their numerics
//!    against host-computed checksums, then measures real wall-clock
//!    gather/scatter bandwidth through PJRT-CPU for a set of paper
//!    patterns using the 10-run-min protocol.
//! 2. **Paper reproduction (L3):** regenerates every table and figure
//!    of the evaluation section through the simulated platforms,
//!    writing CSV series to `bench_out/`.
//!
//! The summary at the end is what EXPERIMENTS.md records.

use std::path::Path;
use std::time::Instant;

use spatter::backends::{Backend, PjrtBackend};
use spatter::pattern::{table5, Kernel, Pattern};
use spatter::suite::{self, SuiteContext};

fn main() -> spatter::Result<()> {
    let t0 = Instant::now();
    println!("=== Spatter end-to-end driver ===\n");

    // ---- Phase 1: real execution through the AOT artifacts ----
    println!("[1/3] PJRT real execution (L1 Pallas kernel + L2 graph + rust runtime)");
    match PjrtBackend::open_default() {
        Ok(mut pjrt) => {
            let checksum = pjrt.validate()?;
            println!(
                "  numerics: device checksum {checksum:.3} matches host; \
                 Pallas artifact == jnp oracle artifact ✓"
            );
            let cases: Vec<(&str, Kernel, Pattern)> = vec![
                (
                    "STREAM-like (UNIFORM:8:1, d=8)",
                    Kernel::Gather,
                    Pattern::parse("UNIFORM:8:1")?.with_delta(8).with_count(1 << 20),
                ),
                (
                    "strided (UNIFORM:8:8, d=64)",
                    Kernel::Gather,
                    Pattern::parse("UNIFORM:8:8")?.with_delta(64).with_count(1 << 20),
                ),
                (
                    "LULESH-G2 (stride-8)",
                    Kernel::Gather,
                    table5::by_name("LULESH-G2").unwrap().to_pattern(1 << 20),
                ),
                (
                    "AMG-G0 (mostly stride-1)",
                    Kernel::Gather,
                    table5::by_name("AMG-G0").unwrap().to_pattern(1 << 20),
                ),
                (
                    "PENNANT-G4 (broadcast)",
                    Kernel::Gather,
                    table5::by_name("PENNANT-G4").unwrap().to_pattern(1 << 20),
                ),
                (
                    "LULESH-S1 (stride-24 scatter)",
                    Kernel::Scatter,
                    table5::by_name("LULESH-S1").unwrap().to_pattern(1 << 20),
                ),
            ];
            println!(
                "  {:<34} {:>10} {:>12}",
                "pattern", "kernel", "GB/s (wall)"
            );
            for (name, kernel, pat) in cases {
                let r = pjrt.run(&pat, kernel)?;
                println!(
                    "  {:<34} {:>10} {:>12.2}",
                    name,
                    kernel.name(),
                    r.bandwidth_gbs()
                );
            }
        }
        Err(e) => {
            println!("  SKIPPED: {e}");
            println!("  (run `make artifacts` first for the real-execution phase)");
        }
    }

    // ---- Phase 2: the paper's evaluation section ----
    println!("\n[2/3] Reproducing every table and figure (simulated platforms)");
    let ctx = SuiteContext::new(Path::new("bench_out"));
    let report = suite::run("all", &ctx)?;
    println!("{report}");

    // ---- Phase 3: summary ----
    println!("[3/3] Done in {:.1}s", t0.elapsed().as_secs_f64());
    println!("CSV series for every figure/table: bench_out/*.csv");
    println!("Record of paper-vs-measured lives in EXPERIMENTS.md");
    Ok(())
}
