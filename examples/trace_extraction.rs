//! The §2 trace pipeline end-to-end: run the mini-app kernel emulators,
//! extract G/S proxy patterns, classify them, and check them against
//! the paper's own Table 5.
//!
//! ```bash
//! cargo run --release --example trace_extraction
//! ```

use spatter::pattern::table5;
use spatter::trace::extract::extract_from_trace;
use spatter::trace::miniapps;

fn main() {
    let apps = miniapps::run_all(1);
    let mut recovered = 0usize;
    let mut shown = 0usize;
    println!("{:-<78}", "");
    for app in &apps {
        for k in &app.kernels {
            println!(
                "{} :: {}  ({} gathers, {} scatters, {:.1} MB G/S = {:.1}% of traffic)",
                app.app,
                k.kernel,
                k.gather_count(),
                k.scatter_count(),
                k.gs_bytes() as f64 / 1e6,
                k.gs_traffic_fraction() * 100.0
            );
            for p in extract_from_trace(k, 4) {
                shown += 1;
                // Does this match a Table 5 row?
                let known = table5::all()
                    .into_iter()
                    .find(|t| t.indices == p.indices && t.kernel == p.kernel);
                if known.is_some() {
                    recovered += 1;
                }
                println!(
                    "    {:<9} x{:<8} delta {:<9} {:<16} {}{:?}",
                    p.kernel.name(),
                    p.occurrences,
                    p.delta,
                    p.class.name(),
                    known.map(|t| format!("[= {}] ", t.name)).unwrap_or_default(),
                    &p.indices[..p.indices.len().min(8)],
                );
            }
            println!("{:-<78}", "");
        }
    }
    println!(
        "\n{recovered}/{shown} extracted clusters match a paper Table 5 row \
         exactly (buffer + kernel)."
    );
    println!(
        "This validates the extraction pipeline the paper built on its \
         closed-source QEMU+SVE rig (DESIGN.md §2 substitution)."
    );
}
