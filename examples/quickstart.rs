//! Quickstart: the paper's §3.4 example — a STREAM-like run.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Equivalent to `./spatter -k Gather -p UNIFORM:8:1 -d 8 -l $((2**24))`:
//! 2^24 gathers, each 8 doubles beyond the last, index buffer of
//! length 8 with uniform stride 1 — a STREAM-Copy-like read bandwidth.

use spatter::backends::{Backend, OpenMpSim};
use spatter::pattern::{Kernel, Pattern};
use spatter::platforms;

fn main() -> spatter::Result<()> {
    // Build the paper's example pattern.
    let pattern = Pattern::parse("UNIFORM:8:1")?
        .with_delta(8)
        .with_count(1 << 24);
    pattern.validate()?;
    println!(
        "pattern {:?}, delta {}, {} gathers -> {:.1} MB of useful data",
        pattern.indices,
        pattern.delta,
        pattern.count,
        pattern.moved_bytes() as f64 / 1e6
    );

    // Run it on every simulated CPU platform.
    println!("\n{:<10} {:>12} {:>12} {:>10}", "platform", "GB/s", "STREAM", "ratio");
    for p in platforms::cpus() {
        let mut backend = OpenMpSim::new(&p);
        let r = backend.run(&pattern, Kernel::Gather)?;
        println!(
            "{:<10} {:>12.2} {:>12.2} {:>9.2}x",
            p.name,
            r.bandwidth_gbs(),
            p.stream_gbs,
            r.bandwidth_gbs() / p.stream_gbs
        );
    }
    println!("\nstride-1 gather tracks each platform's STREAM bandwidth — the");
    println!("paper's sanity anchor before exploring irregular patterns.");
    Ok(())
}
