//! Application-derived patterns (the §5.4 study): run the Table 5
//! proxy patterns of one mini-app across every platform, relative to
//! each platform's stride-1 bandwidth — a terminal rendition of the
//! Fig 7/8 radar charts.
//!
//! ```bash
//! cargo run --release --example app_patterns -- [AMG|Nekbone|LULESH|PENNANT]
//! ```

use spatter::backends::{Backend, CudaSim, OpenMpSim};
use spatter::pattern::{table5, Kernel, Pattern};
use spatter::platforms::{self, Platform};
use spatter::report::RadarChart;
use spatter::stats;

fn run_on(platform: &Platform, pattern: &Pattern, kernel: Kernel) -> spatter::Result<f64> {
    Ok(match platform {
        Platform::Cpu(c) => OpenMpSim::new(c).run(pattern, kernel)?.bandwidth_gbs(),
        Platform::Gpu(g) => CudaSim::new(g).run(pattern, kernel)?.bandwidth_gbs(),
    })
}

fn stride1(platform: &Platform) -> spatter::Result<f64> {
    let (v, count) = if platform.is_gpu() { (256, 1 << 13) } else { (8, 1 << 18) };
    let p = Pattern::parse(&format!("UNIFORM:{v}:1"))?
        .with_delta(v as i64)
        .with_count(count);
    run_on(platform, &p, Kernel::Gather)
}

fn main() -> spatter::Result<()> {
    let app = std::env::args().nth(1).unwrap_or_else(|| "PENNANT".into());
    let pats = table5::by_app(&app);
    if pats.is_empty() {
        eprintln!("unknown app '{app}' (AMG|Nekbone|LULESH|PENNANT)");
        std::process::exit(1);
    }
    let plats = platforms::all();
    let mut refs = Vec::new();
    for p in &plats {
        refs.push(stride1(p)?);
    }

    let mut per_plat: Vec<Vec<f64>> = vec![Vec::new(); plats.len()];
    for pat in &pats {
        let runnable = pat.to_pattern(1 << 16);
        let mut chart = RadarChart::new(pat.name);
        for (i, p) in plats.iter().enumerate() {
            let bw = run_on(p, &runnable, pat.kernel)?;
            chart.add(p.name(), p.is_gpu(), bw, refs[i]);
            per_plat[i].push(bw);
        }
        println!("{}", chart.render_text());
    }

    println!("harmonic means over {} {} patterns:", pats.len(), app);
    for (i, p) in plats.iter().enumerate() {
        let h = stats::harmonic_mean(&per_plat[i]).unwrap_or(0.0);
        println!(
            "  {:>8}: {:>8.1} GB/s  (STREAM {:>6.1}, ratio {:.2})",
            p.name(),
            h,
            p.stream_gbs(),
            h / p.stream_gbs()
        );
    }
    println!(
        "\nPaper takeaway: cached patterns (AMG/Nekbone) beat STREAM on \
         CPUs; PENNANT's large deltas and LULESH's delta-0 scatter crush it."
    );
    Ok(())
}
