//! Uniform-stride sweep (the Fig 3 / Fig 5 experiment) on one platform.
//!
//! ```bash
//! cargo run --release --example uniform_sweep -- [platform] [gather|scatter] [page-size]
//! cargo run --release --example uniform_sweep -- p100 gather     # GPU model
//! cargo run --release --example uniform_sweep -- knl gather 2MB  # huge pages
//! ```
//!
//! The third argument drives the `--page-size` knob of the simulated
//! virtual-memory subsystem (4KB | 64KB | 2MB | 1GB). Compare
//! `knl gather 4KB` against `knl gather 2MB` on a huge-delta pattern
//! (or run `spatter --suite pagesize`) to watch translation stop being
//! the binding resource.
//!
//! Prints the bandwidth curve with a log-style bar so the halving per
//! stride doubling — and each platform's deviation from it — is
//! visible in the terminal.

use spatter::backends::{Backend, CudaSim, OpenMpSim};
use spatter::pattern::{Kernel, Pattern};
use spatter::platforms::{self, Platform};
use spatter::sim::PageSize;

fn main() -> spatter::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let plat = args.first().map(|s| s.as_str()).unwrap_or("skx");
    let kernel = match args.get(1).map(|s| s.as_str()) {
        Some("scatter") => Kernel::Scatter,
        _ => Kernel::Gather,
    };
    let page: Option<PageSize> = match args.get(2) {
        Some(s) => Some(PageSize::parse(s)?),
        None => None,
    };
    let platform = platforms::any_by_name(plat)?;

    let (v, count) = if platform.is_gpu() {
        (256usize, 1 << 14)
    } else {
        (8usize, 1 << 20)
    };

    println!(
        "uniform-stride {} sweep on {} ({}){}\n",
        kernel.name().to_lowercase(),
        platform.name(),
        platform.full_name(),
        match page {
            Some(p) => format!(", {p} pages"),
            None => String::new(),
        }
    );
    println!("{:>7} {:>12}  {}", "stride", "GB/s", "log-scale");
    let mut peak = 0.0f64;
    let mut rows = Vec::new();
    for stride in [1usize, 2, 4, 8, 16, 32, 64, 128] {
        let pattern = Pattern::parse(&format!("UNIFORM:{v}:{stride}"))?
            .with_delta((v * stride) as i64)
            .with_count(count);
        let bw = match &platform {
            Platform::Cpu(c) => {
                let mut b = match page {
                    Some(p) => OpenMpSim::with_page_size(c, p),
                    None => OpenMpSim::new(c),
                };
                b.run(&pattern, kernel)?.bandwidth_gbs()
            }
            Platform::Gpu(g) => {
                let mut b = match page {
                    Some(p) => CudaSim::with_page_size(g, p),
                    None => CudaSim::new(g),
                };
                b.run(&pattern, kernel)?.bandwidth_gbs()
            }
        };
        peak = peak.max(bw);
        rows.push((stride, bw));
    }
    for (stride, bw) in rows {
        // log bar: 40 chars spans 3 decades below peak
        let frac = (bw / peak).log10() / 3.0 + 1.0;
        let n = (frac.clamp(0.0, 1.0) * 40.0) as usize;
        println!("{stride:>7} {bw:>12.2}  {}", "#".repeat(n));
    }
    println!(
        "\npeak/floor ratio: {:.1}x — compare platforms to see who holds \
         bandwidth at intermediate strides (paper Fig 3/5).",
        peak
    );
    Ok(())
}
